//! Compute engines behind the coordinator: the PJRT artifact executor
//! (production) and the pure-Rust reference (tests / grid search).
//!
//! Both implement [`Engine`] — the coordinator is engine-agnostic, which
//! is also how the benches compare "SW-only" vs artifact-backed runs on
//! identical workloads.

use std::cell::RefCell;

use anyhow::Result;

use crate::data::dataset::Sample;
use crate::dfr::backprop::{softmax_inplace, truncated_grads_scratch, GradScratch, OutputLayer};
use crate::dfr::mask::Mask;
use crate::dfr::reservoir::{BatchLane, BatchScratch, ForwardScratch, Nonlinearity, Reservoir};
use crate::runtime::executor::{DfrExecutor, TrainState};
use crate::simd::{global_kernels, Kernels};

/// One lane of a batched feature extraction
/// ([`Engine::features_batch_into`]): a sample plus the session
/// configuration it must run under. Mask and `(p, q)` are per-request
/// because the coordinator batches across sessions, each with its own
/// mask and pinned serving parameters.
#[derive(Clone, Copy, Debug)]
pub struct FeatureRequest<'a> {
    pub sample: &'a Sample,
    pub mask: &'a Mask,
    pub p: f32,
    pub q: f32,
}

/// Ridge scores from a precomputed feature vector: z = W̃·r̃, then
/// softmax. This is the exact tail of [`NativeEngine::infer_into`]
/// (same dot-product op order), factored out so callers holding batched
/// features can score without re-running the forward pass — results are
/// bitwise those of the per-call `infer_into` whenever the engine's
/// [`Engine::scores_from_features_exact`] contract holds.
pub fn scores_from_r_tilde(w_tilde: &[f32], r_tilde: &[f32], scores: &mut Vec<f32>) {
    scores_from_r_tilde_with(w_tilde, r_tilde, scores, &Kernels::scalar());
}

/// [`scores_from_r_tilde`] through an explicit kernel table — callers
/// scoring an engine's batched features pass that engine's
/// [`Engine::kernels`] so the dot products reassociate identically to
/// the engine's own `infer_into`, preserving the bitwise
/// `scores_from_features_exact` contract under any table.
pub fn scores_from_r_tilde_with(
    w_tilde: &[f32],
    r_tilde: &[f32],
    scores: &mut Vec<f32>,
    kernels: &Kernels,
) {
    let sdim = r_tilde.len();
    let ny = w_tilde.len() / sdim;
    scores.clear();
    scores.reserve(ny);
    for i in 0..ny {
        let row = &w_tilde[i * sdim..(i + 1) * sdim];
        scores.push((kernels.dot)(row, r_tilde));
    }
    softmax_inplace(scores);
}

/// The per-call fallback behind [`Engine::features_batch_into`]: a
/// sequential loop over [`Engine::features_into`]. Public so engines
/// that *partially* batch (e.g. `QuantEngine`, whose integer MAC stays
/// per-call — DESIGN.md §14) route their non-batched datapath through
/// the same audited loop as the trait default.
pub fn features_batch_per_call<E: Engine + ?Sized>(
    engine: &E,
    reqs: &[FeatureRequest<'_>],
    outs: &mut [Vec<f32>],
) -> Result<()> {
    assert_eq!(reqs.len(), outs.len(), "reqs/outs length mismatch");
    for (r, out) in reqs.iter().zip(outs.iter_mut()) {
        engine.features_into(r.sample, r.mask, r.p, r.q, out)?;
    }
    Ok(())
}

/// A reservoir-parameter change the Serve-phase adaptation loop reports
/// to its engine ([`Engine::recalibrate`]): the new (p, q) plus the
/// workload envelope the session has observed so far — everything a
/// quantized backend needs to re-run the §12 error budget without a
/// reference trajectory.
#[derive(Clone, Copy, Debug)]
pub struct ReservoirUpdate {
    pub p: f32,
    pub q: f32,
    /// input channels
    pub n_v: usize,
    /// longest series length observed
    pub t_max: usize,
    /// largest |u| observed
    pub u_max: f32,
}

/// What an [`Engine::recalibrate`] call did.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Recalibration {
    /// the engine's reservoir generation after this call — sessions
    /// record it and refuse to mix features/factors across generations
    pub generation: u64,
    /// whether the engine switched its serving datapath to the f32
    /// fallback because the new (p, q) violates its error budget
    pub fell_back: bool,
    /// the re-evaluated per-element r̃ error bound (`None` for engines
    /// without a quantization budget; infinite iff `fell_back`)
    pub error_bound: Option<f32>,
}

/// The operations a session needs from its compute backend.
pub trait Engine: Send {
    /// One truncated-BP SGD step; mutates the train state, returns loss.
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32>;

    /// Ridge feature vector r̃ = [r, 1].
    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>>;

    /// Ridge feature vector into a caller-owned buffer. Engines that
    /// support a zero-allocation steady state override this (the default
    /// delegates to [`features`](Self::features) and copies).
    ///
    /// This is also the extraction path of the Serve-phase streaming
    /// ridge (`Session::observe_online`): with the native override, one
    /// labelled sample costs a forward pass plus O(s²) rank-1 algebra
    /// and **no heap allocations** end to end.
    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let f = self.features(s, mask, p, q)?;
        out.clear();
        out.extend_from_slice(&f);
        Ok(())
    }

    /// Batched feature extraction: fill `outs[i]` with the r̃ of
    /// `reqs[i]`. The default is a per-call loop over
    /// [`features_into`](Self::features_into) — engines with a real
    /// batched kernel override it (NativeEngine runs all requests
    /// through one [`BatchScratch`] sweep). Every override must return
    /// features **bitwise equal** to the per-call path at every batch
    /// size (`tests/batch_equivalence.rs`) — the coordinator treats the
    /// two paths as interchangeable mid-stream.
    fn features_batch_into(
        &self,
        reqs: &[FeatureRequest<'_>],
        outs: &mut [Vec<f32>],
    ) -> Result<()> {
        features_batch_per_call(self, reqs, outs)
    }

    /// The compute-kernel table this engine's float datapath runs on.
    /// Callers that score an engine's features *outside* the engine
    /// (the session's batched-infer path) must dot through this table —
    /// see [`scores_from_r_tilde_with`] — so their reduction order
    /// matches `infer_into` exactly. The default is the portable scalar
    /// table; engines carrying a runtime-dispatched table override it.
    fn kernels(&self) -> Kernels {
        Kernels::scalar()
    }

    /// Whether `scores_from_r_tilde_with(w̃, features, …,
    /// &engine.kernels())` over this engine's `features_into` output
    /// reproduces `infer_into` **bitwise**. True
    /// for engines whose inference is exactly a float dot product over
    /// r̃ (NativeEngine; QuantEngine while fallen back). False when
    /// inference uses a different datapath than dequantized features
    /// (QuantEngine's integer MAC) — callers must then route `Infer`
    /// through the per-call [`infer_into`](Self::infer_into) instead of
    /// scoring batched features.
    fn scores_from_features_exact(&self) -> bool {
        false
    }

    /// Class scores with a ridge output layer W̃ (row-major n_c × s).
    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w_tilde: &[f32])
        -> Result<Vec<f32>>;

    /// Class scores into a caller-owned buffer (see
    /// [`features_into`](Self::features_into) for the contract).
    fn infer_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
        scores: &mut Vec<f32>,
    ) -> Result<()> {
        let z = self.infer(s, mask, p, q, w_tilde)?;
        scores.clear();
        scores.extend_from_slice(&z);
        Ok(())
    }

    /// Human-readable backend name (metrics/logs).
    fn name(&self) -> &'static str;

    /// **Datapath generation** of this engine replica: a monotonic
    /// counter that advances whenever the engine's *shared serving
    /// datapath* changes — e.g. a quantized engine flipping to (or
    /// recovering from) its f32 fallback during
    /// [`recalibrate`](Self::recalibrate).
    ///
    /// Sessions use it to enforce the no-mixing invariant of the online
    /// adaptation loop: a ridge factor seeded under datapath generation
    /// G is only ever fed features extracted under generation G; when
    /// the counter moves (any session on the shard flipping the shared
    /// datapath), every session re-featurizes its buffer and reseeds
    /// before folding anything else. Engines whose datapath is purely
    /// parametric — the feature function depends only on the per-call
    /// (p, q) — return a constant, and per-session parameter changes are
    /// instead tracked by the session's own generation counter.
    fn generation(&self) -> u64 {
        0
    }

    /// Notify the engine that the serve-loop reservoir optimizer moved
    /// (p, q). Backends with parameter-dependent serving state re-derive
    /// it — the quantized engine rebuilds its PWL LUT, re-runs the §12
    /// error budget for the active Q-format, and falls back to f32
    /// serving if the new parameters violate the budget's stability
    /// region, bumping its [`generation`](Self::generation) whenever the
    /// shared datapath actually changes (the fallback flipping either
    /// way). The default is a no-op for purely parametric backends.
    fn recalibrate(&self, _upd: &ReservoirUpdate) -> Result<Recalibration> {
        Ok(Recalibration {
            generation: self.generation(),
            fell_back: false,
            error_bound: None,
        })
    }

    /// Whether the engine is currently serving through a degraded
    /// fallback datapath (the quantized engine's f32 fallback). The
    /// coordinator journals transitions — paired with
    /// [`generation`](Self::generation) moving, this tells fallback
    /// flips apart from recoveries. Purely parametric engines never
    /// fall back.
    fn fell_back(&self) -> bool {
        false
    }

    /// Create an independent replica of this engine for another shard
    /// thread (see `coordinator::server`). Engines whose backend cannot
    /// be replicated return `None`, and the server degrades to fewer
    /// shards. The default is `None` — sharing is opt-in.
    fn fork(&self) -> Option<Box<dyn Engine>> {
        None
    }
}

// ---------------------------------------------------------------------------
// native engine
// ---------------------------------------------------------------------------

/// Pure-Rust engine over `dfr::*` — bit-compatible with the JAX model
/// (golden-tested), no artifacts required.
///
/// Holds a per-replica [`EngineScratch`] so that steady-state
/// `features`/`infer` requests perform **zero heap allocations** beyond
/// the returned vector (and *none at all* through the `_into` variants)
/// — asserted by the counting-allocator test in `tests/zero_alloc.rs`.
pub struct NativeEngine {
    pub nx: usize,
    pub n_c: usize,
    pub f: Nonlinearity,
    /// Compute-kernel table for the batched forward sweep and the score
    /// dots (the process selection unless pinned via
    /// [`with_kernels`](Self::with_kernels)); `fork` propagates it, so
    /// every shard replica runs the same table.
    kernels: Kernels,
    /// Each shard exclusively owns its engine replica (`Engine: Send`,
    /// not `Sync`), so this RefCell is never contended — it exists only
    /// because `Engine` methods take `&self`.
    scratch: RefCell<EngineScratch>,
}

/// Reusable per-replica buffers: a reservoir whose mask is refreshed in
/// place, the forward workspace, r̃, an output-layer copy for the
/// backward pass, and the gradient workspace.
struct EngineScratch {
    res: Reservoir,
    fwd: ForwardScratch,
    /// batched-forward workspace (grow-only; empty until the first
    /// `features_batch_into`)
    bfwd: BatchScratch,
    r_tilde: Vec<f32>,
    out: OutputLayer,
    gsc: GradScratch,
}

impl NativeEngine {
    pub fn new(nx: usize, n_c: usize) -> Self {
        Self::with_nonlinearity(nx, n_c, Nonlinearity::Linear { alpha: 1.0 })
    }

    pub fn with_nonlinearity(nx: usize, n_c: usize, f: Nonlinearity) -> Self {
        Self::with_kernels(nx, n_c, f, global_kernels())
    }

    /// An engine pinned to an explicit kernel table (the CLI's resolved
    /// `--simd` selection, or a test pinning scalar/AVX2 directly);
    /// [`with_nonlinearity`](Self::with_nonlinearity) takes the
    /// process-wide selection.
    pub fn with_kernels(nx: usize, n_c: usize, f: Nonlinearity, kernels: Kernels) -> Self {
        NativeEngine {
            nx,
            n_c,
            f,
            kernels,
            scratch: RefCell::new(EngineScratch {
                res: Reservoir {
                    mask: Mask {
                        nx,
                        v: 0,
                        m: Vec::new(),
                    },
                    p: 0.0,
                    q: 0.0,
                    f,
                },
                fwd: ForwardScratch::new(nx),
                bfwd: BatchScratch::new(),
                r_tilde: Vec::new(),
                out: OutputLayer::zeros(n_c, nx),
                gsc: GradScratch::new(),
            }),
        }
    }

    /// Run the reservoir forward into the replica workspace. Zero heap
    /// allocations in steady state: the session's mask is copied in
    /// place (derived `Clone::clone_from` would reallocate), and a
    /// reallocation happens only when the mask *shape* changes.
    fn forward_scratch(&self, s: &Sample, mask: &Mask, p: f32, q: f32, sc: &mut EngineScratch) {
        if sc.res.mask.nx != mask.nx || sc.res.mask.v != mask.v {
            sc.res.mask = mask.clone();
        } else if sc.res.mask.m != mask.m {
            sc.res.mask.m.copy_from_slice(&mask.m);
        }
        sc.res.p = p;
        sc.res.q = q;
        sc.res.f = self.f;
        sc.res.forward_into(&s.u, s.t, &mut sc.fwd);
    }
}

impl Engine for NativeEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        let mut sc = self.scratch.borrow_mut();
        self.forward_scratch(s, mask, state.p, state.q, &mut sc);
        // refresh the output-layer copy in place (no per-step clone)
        if sc.out.w.len() != state.w.len() {
            sc.out.w.resize(state.w.len(), 0.0);
        }
        sc.out.w.copy_from_slice(&state.w);
        if sc.out.b.len() != state.b.len() {
            sc.out.b.resize(state.b.len(), 0.0);
        }
        sc.out.b.copy_from_slice(&state.b);
        sc.out.ny = self.n_c;
        sc.out.nr = self.nx * (self.nx + 1);
        // split borrow: forward view, output copy and gradient workspace
        // are distinct fields — the backward pass runs fully in place
        let EngineScratch { fwd, out, gsc, .. } = &mut *sc;
        truncated_grads_scratch(
            fwd.as_forward_ref(),
            s.label,
            state.p,
            state.q,
            self.f,
            out,
            gsc,
        );
        let g = gsc.grads();
        // same ±1 clip as the train_step artifact (model.GRAD_CLIP)
        let clip = 1.0f32;
        let (dp, dq) = (g.dp.clamp(-clip, clip), g.dq.clamp(-clip, clip));
        if dp.is_finite() && dq.is_finite() {
            state.p -= lr_res * dp;
            state.q -= lr_res * dq;
        }
        if g.loss.is_finite() {
            for (w, d) in state.w.iter_mut().zip(&g.dw) {
                *w -= lr_out * d;
            }
            for (b, d) in state.b.iter_mut().zip(&g.db) {
                *b -= lr_out * d;
            }
        }
        Ok(g.loss)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.features_into(s, mask, p, q, &mut out)?;
        Ok(out)
    }

    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let mut sc = self.scratch.borrow_mut();
        self.forward_scratch(s, mask, p, q, &mut sc);
        sc.fwd.r_tilde_into(out);
        Ok(())
    }

    fn features_batch_into(
        &self,
        reqs: &[FeatureRequest<'_>],
        outs: &mut [Vec<f32>],
    ) -> Result<()> {
        assert_eq!(reqs.len(), outs.len(), "reqs/outs length mismatch");
        if reqs.is_empty() {
            return Ok(());
        }
        // One node-major sweep over all lanes: the sequential
        // virtual-node recurrence runs once per step for the whole
        // batch. Per lane the op sequence is identical to
        // `features_into` under every kernel table (the AVX2 cascade
        // kernel preserves per-lane op order — DESIGN.md §18), so the
        // outputs are bitwise equal.
        let mut sc = self.scratch.borrow_mut();
        sc.bfwd.forward_batch_into_with(
            self.f,
            reqs.len(),
            |l| {
                let r = &reqs[l];
                BatchLane {
                    u: &r.sample.u,
                    t: r.sample.t,
                    mask: r.mask,
                    p: r.p,
                    q: r.q,
                }
            },
            &self.kernels,
        );
        for (l, out) in outs.iter_mut().enumerate() {
            sc.bfwd.r_tilde_into(l, out);
        }
        Ok(())
    }

    fn scores_from_features_exact(&self) -> bool {
        // `infer_into` is exactly `scores_from_r_tilde` over
        // `features_into` output — scoring batched features per lane
        // reproduces per-call inference bitwise
        true
    }

    fn infer(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
    ) -> Result<Vec<f32>> {
        let mut z = Vec::new();
        self.infer_into(s, mask, p, q, w_tilde, &mut z)?;
        Ok(z)
    }

    fn infer_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
        scores: &mut Vec<f32>,
    ) -> Result<()> {
        let mut sc = self.scratch.borrow_mut();
        self.forward_scratch(s, mask, p, q, &mut sc);
        // split borrow: r̃ buffer and forward workspace are distinct fields
        let EngineScratch { fwd, r_tilde, .. } = &mut *sc;
        fwd.r_tilde_into(r_tilde);
        scores_from_r_tilde_with(w_tilde, r_tilde, scores, &self.kernels);
        Ok(())
    }

    fn kernels(&self) -> Kernels {
        self.kernels
    }

    fn name(&self) -> &'static str {
        "native"
    }

    // `generation`/`recalibrate` keep the trait defaults: the f32
    // datapath is purely parametric — (p, q) arrive per call, so a
    // reservoir-parameter change never alters the shared datapath and
    // other sessions on the shard have nothing to re-featurize against.

    fn fork(&self) -> Option<Box<dyn Engine>> {
        // stateless apart from its dimensions and kernel table (each
        // replica gets its own workspace) — replicas are free
        Some(Box::new(NativeEngine::with_kernels(
            self.nx,
            self.n_c,
            self.f,
            self.kernels,
        )))
    }
}

// ---------------------------------------------------------------------------
// PJRT engine
// ---------------------------------------------------------------------------

/// Artifact-backed engine: every operation is a PJRT execution of the
/// HLO compiled from the L2 JAX model (which itself calls the L1 Pallas
/// kernels). The request path is pure Rust + XLA.
pub struct PjrtEngine {
    pub exec: DfrExecutor,
}

impl PjrtEngine {
    pub fn new(exec: DfrExecutor) -> Self {
        PjrtEngine { exec }
    }
}

// SAFETY: the xla crate wraps the PJRT client in `Rc` (not thread-safe
// reference counting), so `DfrExecutor` is !Send by construction. The
// coordinator moves each engine replica into exactly one shard thread
// and never aliases it across threads afterwards (Engine methods take
// &self but each shard holds the sole owner of its replica; `fork`
// compiles a fresh client rather than sharing this one); the underlying
// PJRT CPU client itself is a single-process C API object that tolerates
// use from the one thread that owns it. Moving ownership between threads
// is therefore sound.
unsafe impl Send for PjrtEngine {}

impl Engine for PjrtEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        self.exec.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        self.exec.features(s, mask, p, q)
    }

    fn infer(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        w_tilde: &[f32],
    ) -> Result<Vec<f32>> {
        self.exec.infer(s, mask, p, q, w_tilde)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn fork(&self) -> Option<Box<dyn Engine>> {
        // The Rc-based PJRT client cannot be shared across threads, but a
        // replica can be compiled from the same artifacts — each shard
        // then owns a whole client. Compilation failure (or a stub
        // build) just means fewer shards.
        DfrExecutor::new(&self.exec.profile)
            .ok()
            .map(|exec| Box::new(PjrtEngine::new(exec)) as Box<dyn Engine>)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn sample(t: usize, v: usize, seed: u64, label: usize) -> Sample {
        let mut rng = Pcg32::seed(seed);
        Sample {
            u: (0..t * v).map(|_| rng.normal()).collect(),
            t,
            label,
        }
    }

    #[test]
    fn native_train_step_moves_state() {
        let eng = NativeEngine::new(8, 3);
        let mask = Mask::golden(8, 2);
        let mut st = TrainState::init(3, 8, 0.1, 0.1);
        let s = sample(12, 2, 1, 1);
        // after a first step W becomes nonzero, after a second p/q move
        let l1 = eng.train_step(&s, &mask, &mut st, 0.1, 0.1).unwrap();
        assert!(l1.is_finite());
        assert!(st.w.iter().any(|&w| w != 0.0));
        let before = (st.p, st.q);
        eng.train_step(&s, &mask, &mut st, 0.1, 0.1).unwrap();
        assert!((st.p, st.q) != before);
    }

    #[test]
    fn native_infer_is_probability() {
        let eng = NativeEngine::new(6, 2);
        let mask = Mask::golden(6, 2);
        let s = sample(9, 2, 2, 0);
        let sdim = 6 * 7 + 1;
        let w = vec![0.01f32; 2 * sdim];
        let y = eng.infer(&s, &mask, 0.2, 0.1, &w).unwrap();
        assert_eq!(y.len(), 2);
        assert!((y.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn native_recalibrate_is_a_parametric_no_op() {
        // the f32 datapath takes (p, q) per call — recalibration never
        // changes the shared datapath, so the generation stays put and
        // other sessions on the shard are not forced to reseed
        let eng = NativeEngine::new(6, 2);
        assert_eq!(eng.generation(), 0);
        let upd = ReservoirUpdate {
            p: 0.2,
            q: 0.1,
            n_v: 2,
            t_max: 10,
            u_max: 1.0,
        };
        let r = eng.recalibrate(&upd).unwrap();
        assert!(!r.fell_back);
        assert_eq!(r.error_bound, None);
        assert_eq!(r.generation, 0);
        assert_eq!(eng.generation(), 0);
    }

    #[test]
    fn native_matches_train_module_forward() {
        // engine features == dfr::train sample features
        let eng = NativeEngine::new(5, 2);
        let mask = Mask::golden(5, 3);
        let s = sample(7, 3, 3, 0);
        let f1 = eng.features(&s, &mask, 0.25, 0.2).unwrap();
        let res = Reservoir {
            mask: mask.clone(),
            p: 0.25,
            q: 0.2,
            f: Nonlinearity::Linear { alpha: 1.0 },
        };
        let f2 = res.forward(&s.u, s.t).r_tilde();
        assert_eq!(f1, f2);
    }
}
