//! Request/response protocol of the online edge service.
//!
//! Requests that carry a session id ([`Request::session_id`]) are routed
//! to shard `id % shards` by the server. `Stats` is answered inline by
//! the server handle from the shared metrics registry (which aggregates
//! every shard's labelled instruments) without entering any queue;
//! `Shutdown` markers are delivered per shard by `Server::shutdown`.

use crate::data::dataset::Sample;

/// Client-visible requests.
#[derive(Debug)]
pub enum Request {
    /// A labelled sample for online training (Collect/BpOptimize phases).
    Labelled { session: u64, sample: Sample },
    /// An unlabelled sample for inference (Serve phase).
    Infer { session: u64, sample: Sample },
    /// Force the session to finish collecting and train now.
    Finalize { session: u64 },
    /// Metrics snapshot.
    Stats,
    /// Drain marker used by `Server::shutdown`: the receiving shard
    /// answers everything queued ahead of it, acks with `Bye`, and keeps
    /// serving until the server drops its queue. Sending this through
    /// `call` only drains/acks one shard — use `Server::shutdown` to
    /// actually stop the server.
    Shutdown,
}

/// Responses (sent back over the per-request channel).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Sample accepted; current phase echoed.
    Accepted { phase: &'static str, buffered: usize },
    /// Prediction with class scores.
    Prediction { class: usize, scores: Vec<f32> },
    /// Session transitioned into Serve (training finished).
    Trained {
        p: f32,
        q: f32,
        beta: f32,
        train_seconds: f64,
    },
    /// Serve-phase streaming update applied: the labelled sample was
    /// folded into the session's online ridge accumulator (rank-1
    /// Cholesky update + in-place re-solve) without leaving Serve.
    /// `updates` counts the accumulator's lifetime folds; `window` is
    /// the ring occupancy in sliding-window mode and equals the
    /// lifetime fold count in λ-forgetting mode (where every past
    /// sample remains in the system at geometrically decayed weight).
    Observed { updates: u64, window: usize },
    /// Serve-phase reservoir adaptation rolled the session onto a new
    /// reservoir **generation**: the streaming truncated-BPTT optimizer's
    /// accumulated (p, q) drift crossed the threshold (or the engine's
    /// datapath generation moved), the engine recalibrated — quantized
    /// backends re-run the §12 error budget and may fall back to f32 —
    /// and the session re-featurized its recent-sample ring through the
    /// updated reservoir and reseeded the online ridge factor from it.
    /// `updates` is the number of buffered samples re-folded into the
    /// fresh factor; `p`/`q` are the new serving parameters.
    Adapted {
        generation: u64,
        p: f32,
        q: f32,
        updates: u64,
    },
    /// Metrics text.
    StatsText(String),
    /// Request rejected (backpressure or bad session state).
    Rejected(String),
    /// The request was accepted but processing failed — a panic was
    /// caught and isolated, the engine returned an error, or a
    /// non-finite value was quarantined. Unlike `Rejected` (the input
    /// was bad), `Error` means the *server* faulted on a well-formed
    /// request: the session is flagged degraded and self-heals through
    /// the batch-fallback/reseed path on its next labelled sample, so
    /// the caller may simply retry.
    Error { kind: ErrorKind, detail: String },
    /// Acknowledged shutdown.
    Bye,
}

/// Failure class carried by [`Response::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Processing panicked; the panic was caught at the shard boundary
    /// (`request_panics_total`).
    Panic,
    /// The engine returned a typed error mid-request.
    Engine,
    /// A non-finite feature/score was produced and quarantined
    /// (`nonfinite_quarantined_total`).
    NonFinite,
}

impl Request {
    pub fn session_id(&self) -> Option<u64> {
        match self {
            Request::Labelled { session, .. }
            | Request::Infer { session, .. }
            | Request::Finalize { session } => Some(*session),
            Request::Stats | Request::Shutdown => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_routing_key() {
        let s = Sample {
            u: vec![0.0],
            t: 1,
            label: 0,
        };
        assert_eq!(Request::Labelled { session: 7, sample: s }.session_id(), Some(7));
        assert_eq!(Request::Stats.session_id(), None);
    }
}
