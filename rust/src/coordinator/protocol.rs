//! Request/response protocol of the online edge service, plus its wire
//! codec.
//!
//! Requests that carry a session id ([`Request::session_id`]) are routed
//! to shard `id % shards` by the server. `Stats` is answered inline by
//! the server handle from the shared metrics registry (which aggregates
//! every shard's labelled instruments) without entering any queue;
//! `Shutdown` markers are delivered per shard by `Server::shutdown`.
//!
//! The wire codec ([`encode_request`]/[`decode_request`] and the
//! response pair) is the payload layer of the TCP front
//! (`coordinator::net`): one tag byte, then little-endian fixed-width
//! fields. Vectors are a `u32` length followed by raw `f32` words,
//! capped at [`MAX_VEC`] elements; strings are a `u32` byte length
//! followed by UTF-8. Every malformed input decodes to a typed
//! [`WireError`] — never a panic — because these bytes arrive from the
//! network, not from our own process.

use std::fmt;

use crate::coordinator::session::Phase;
use crate::data::dataset::Sample;

/// Client-visible requests.
#[derive(Debug, PartialEq)]
pub enum Request {
    /// A labelled sample for online training (Collect/BpOptimize phases).
    Labelled { session: u64, sample: Sample },
    /// An unlabelled sample for inference (Serve phase).
    Infer { session: u64, sample: Sample },
    /// Force the session to finish collecting and train now.
    Finalize { session: u64 },
    /// Metrics snapshot.
    Stats,
    /// The newest `n` completed request traces as JSON lines (one
    /// object per line; see `util::trace::TraceRecord::to_json_line`).
    /// Answered inline by the server handle from the shared trace hub —
    /// never queued, so traces stay readable while shards are saturated.
    Traces { n: usize },
    /// The newest `n` operational events (shard deaths/respawns,
    /// generation rolls, quant fallback flips, quarantines, hibernation
    /// churn, checkpoint writes) as JSON lines. Answered inline like
    /// `Traces`.
    Events { n: usize },
    /// Internal liveness probe used by the `/readyz` endpoint: enqueued
    /// per shard to verify the queue accepts work; the shard answers
    /// `Bye` immediately. Like `Shutdown` it has no wire tag and is
    /// rejected by the public call paths — only the health prober sends
    /// it, with a reply channel it may drop.
    Ping,
    /// Drain marker used by `Server::shutdown`: the receiving shard
    /// answers everything queued ahead of it, acks with `Bye`, and keeps
    /// serving until the server drops its queue. Sending this through
    /// `call` only drains/acks one shard, so the public call paths
    /// reject it with a typed `Rejected` and the wire codec refuses to
    /// carry it at all ([`WireError::NotWire`]) — use `Server::shutdown`
    /// to actually stop the server.
    Shutdown,
}

/// Responses (sent back over the per-request channel).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Sample accepted; current phase echoed.
    Accepted { phase: &'static str, buffered: usize },
    /// Prediction with class scores.
    Prediction { class: usize, scores: Vec<f32> },
    /// Session transitioned into Serve (training finished).
    Trained {
        p: f32,
        q: f32,
        beta: f32,
        train_seconds: f64,
    },
    /// Serve-phase streaming update applied: the labelled sample was
    /// folded into the session's online ridge accumulator (rank-1
    /// Cholesky update + in-place re-solve) without leaving Serve.
    /// `updates` counts the accumulator's lifetime folds; `window` is
    /// the ring occupancy in sliding-window mode and equals the
    /// lifetime fold count in λ-forgetting mode (where every past
    /// sample remains in the system at geometrically decayed weight).
    Observed { updates: u64, window: usize },
    /// Serve-phase reservoir adaptation rolled the session onto a new
    /// reservoir **generation**: the streaming truncated-BPTT optimizer's
    /// accumulated (p, q) drift crossed the threshold (or the engine's
    /// datapath generation moved), the engine recalibrated — quantized
    /// backends re-run the §12 error budget and may fall back to f32 —
    /// and the session re-featurized its recent-sample ring through the
    /// updated reservoir and reseeded the online ridge factor from it.
    /// `updates` is the number of buffered samples re-folded into the
    /// fresh factor; `p`/`q` are the new serving parameters.
    Adapted {
        generation: u64,
        p: f32,
        q: f32,
        updates: u64,
    },
    /// Metrics text.
    StatsText(String),
    /// Trace dump: JSON lines, newest-last (`Request::Traces`).
    Traces(String),
    /// Event-journal dump: JSON lines, newest-last (`Request::Events`).
    Events(String),
    /// Request rejected (backpressure or bad session state).
    Rejected(String),
    /// The request was accepted but processing failed — a panic was
    /// caught and isolated, the engine returned an error, or a
    /// non-finite value was quarantined. Unlike `Rejected` (the input
    /// was bad), `Error` means the *server* faulted on a well-formed
    /// request: the session is flagged degraded and self-heals through
    /// the batch-fallback/reseed path on its next labelled sample, so
    /// the caller may simply retry.
    Error { kind: ErrorKind, detail: String },
    /// Acknowledged shutdown.
    Bye,
}

/// Failure class carried by [`Response::Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Processing panicked; the panic was caught at the shard boundary
    /// (`request_panics_total`).
    Panic,
    /// The engine returned a typed error mid-request.
    Engine,
    /// A non-finite feature/score was produced and quarantined
    /// (`nonfinite_quarantined_total`).
    NonFinite,
}

impl Request {
    pub fn session_id(&self) -> Option<u64> {
        match self {
            Request::Labelled { session, .. }
            | Request::Infer { session, .. }
            | Request::Finalize { session } => Some(*session),
            Request::Stats
            | Request::Traces { .. }
            | Request::Events { .. }
            | Request::Ping
            | Request::Shutdown => None,
        }
    }

    /// Trace kind code — the `REQ_*` wire tag for wire-encodable
    /// variants, 0 for internal markers (`Ping`, `Shutdown`). Mirrored
    /// by `util::trace::kind_name`.
    pub fn kind_code(&self) -> u8 {
        match self {
            Request::Labelled { .. } => REQ_LABELLED,
            Request::Infer { .. } => REQ_INFER,
            Request::Finalize { .. } => REQ_FINALIZE,
            Request::Stats => REQ_STATS,
            Request::Traces { .. } => REQ_TRACES,
            Request::Events { .. } => REQ_EVENTS,
            Request::Ping | Request::Shutdown => 0,
        }
    }
}

impl Response {
    /// Trace outcome code — the `RESP_*` wire tag. Mirrored by
    /// `util::trace::outcome_name`.
    pub fn kind_code(&self) -> u8 {
        match self {
            Response::Accepted { .. } => RESP_ACCEPTED,
            Response::Prediction { .. } => RESP_PREDICTION,
            Response::Trained { .. } => RESP_TRAINED,
            Response::Observed { .. } => RESP_OBSERVED,
            Response::Adapted { .. } => RESP_ADAPTED,
            Response::StatsText(_) => RESP_STATS_TEXT,
            Response::Traces(_) => RESP_TRACES,
            Response::Events(_) => RESP_EVENTS,
            Response::Rejected(_) => RESP_REJECTED,
            Response::Error { .. } => RESP_ERROR,
            Response::Bye => RESP_BYE,
        }
    }
}

impl ErrorKind {
    fn code(self) -> u8 {
        match self {
            ErrorKind::Panic => 0,
            ErrorKind::Engine => 1,
            ErrorKind::NonFinite => 2,
        }
    }

    fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(ErrorKind::Panic),
            1 => Some(ErrorKind::Engine),
            2 => Some(ErrorKind::NonFinite),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// wire codec

/// Hard cap on any wire-carried vector/string length (elements for f32
/// vectors, bytes for strings). Mirrors the net layer's frame-size
/// bound: a hostile length prefix must not drive allocation.
pub const MAX_VEC: usize = 1 << 24;

/// Typed wire-codec failure. Anything the network hands us that is not
/// a well-formed message lands here — decoding never panics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// unknown message tag byte
    BadTag(u8),
    /// payload ended mid-field
    Truncated,
    /// a field decoded but its value is unusable (bad UTF-8, zero-length
    /// sample, absurd vector length, unknown phase/error-kind code)
    Invalid(String),
    /// the variant is deliberately not wire-encodable
    NotWire(&'static str),
    /// a complete message decoded but bytes were left over
    TrailingBytes(usize),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::BadTag(tag) => write!(f, "wire: unknown message tag {tag}"),
            WireError::Truncated => write!(f, "wire: payload truncated mid-field"),
            WireError::Invalid(msg) => write!(f, "wire: invalid field: {msg}"),
            WireError::NotWire(msg) => write!(f, "wire: not encodable: {msg}"),
            WireError::TrailingBytes(n) => {
                write!(f, "wire: {n} trailing bytes after a complete message")
            }
        }
    }
}

impl std::error::Error for WireError {}

// -- little-endian field writers --------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(buf: &mut Vec<u8>, v: usize) {
    put_u64(buf, v as u64);
}

fn put_f32s(buf: &mut Vec<u8>, v: &[f32]) -> Result<(), WireError> {
    if v.len() > MAX_VEC {
        return Err(WireError::Invalid(format!(
            "vector of {} f32s exceeds the {MAX_VEC}-element wire cap",
            v.len()
        )));
    }
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_f32(buf, x);
    }
    Ok(())
}

fn put_str(buf: &mut Vec<u8>, s: &str) -> Result<(), WireError> {
    if s.len() > MAX_VEC {
        return Err(WireError::Invalid(format!(
            "string of {} bytes exceeds the {MAX_VEC}-byte wire cap",
            s.len()
        )));
    }
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

fn put_sample(buf: &mut Vec<u8>, s: &Sample) -> Result<(), WireError> {
    if s.t == 0 {
        // t divides the virtual-node interval; a zero would fault the
        // datapath, so it is rejected at the codec on BOTH directions
        return Err(WireError::Invalid("sample t must be >= 1".into()));
    }
    let t = u32::try_from(s.t)
        .map_err(|_| WireError::Invalid(format!("sample t {} exceeds u32", s.t)))?;
    let label = u32::try_from(s.label)
        .map_err(|_| WireError::Invalid(format!("sample label {} exceeds u32", s.label)))?;
    put_u32(buf, t);
    put_u32(buf, label);
    put_f32s(buf, &s.u)
}

// -- bounds-checked reader --------------------------------------------

struct WireReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> WireReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.at.checked_add(n).ok_or(WireError::Truncated)?;
        if end > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let out = &self.buf[self.at..end];
        self.at = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.u32()?.to_le_bytes()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.u64()?.to_le_bytes()))
    }

    fn usize(&mut self) -> Result<usize, WireError> {
        usize::try_from(self.u64()?)
            .map_err(|_| WireError::Invalid("u64 field does not fit usize".into()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        if n > MAX_VEC {
            return Err(WireError::Invalid(format!(
                "claimed vector length {n} exceeds the {MAX_VEC}-element wire cap"
            )));
        }
        // cap the pre-allocation by the bytes actually present, so a
        // hostile length prefix cannot force a large allocation before
        // take() reports the truncation
        let mut out = Vec::with_capacity(n.min((self.buf.len() - self.at) / 4));
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        if n > MAX_VEC {
            return Err(WireError::Invalid(format!(
                "claimed string length {n} exceeds the {MAX_VEC}-byte wire cap"
            )));
        }
        let bytes = self.take(n)?;
        std::str::from_utf8(bytes)
            .map(str::to_string)
            .map_err(|_| WireError::Invalid("string field is not UTF-8".into()))
    }

    fn sample(&mut self) -> Result<Sample, WireError> {
        let t = self.u32()? as usize;
        if t == 0 {
            return Err(WireError::Invalid("sample t must be >= 1".into()));
        }
        let label = self.u32()? as usize;
        let u = self.f32s()?;
        Ok(Sample { u, t, label })
    }

    fn finish(self) -> Result<(), WireError> {
        let rest = self.buf.len() - self.at;
        if rest > 0 {
            return Err(WireError::TrailingBytes(rest));
        }
        Ok(())
    }
}

/// Recover the `&'static str` phase name the `Accepted` response
/// carries: match the wire string back through [`Phase`]'s four names.
fn static_phase(name: &str) -> Result<&'static str, WireError> {
    for code in 0..4u8 {
        if let Some(p) = Phase::from_code(code) {
            if p.name() == name {
                return Ok(p.name());
            }
        }
    }
    Err(WireError::Invalid(format!("unknown phase {name:?}")))
}

const REQ_LABELLED: u8 = 1;
const REQ_INFER: u8 = 2;
const REQ_FINALIZE: u8 = 3;
const REQ_STATS: u8 = 4;
const REQ_TRACES: u8 = 5;
const REQ_EVENTS: u8 = 6;

/// Encode a request payload (no frame header — `coordinator::net` adds
/// that). `Shutdown` is refused: it is a process-local drain marker, and
/// a remote peer must never be able to stall a shard.
pub fn encode_request(req: &Request) -> Result<Vec<u8>, WireError> {
    let mut buf = Vec::new();
    match req {
        Request::Labelled { session, sample } => {
            buf.push(REQ_LABELLED);
            put_u64(&mut buf, *session);
            put_sample(&mut buf, sample)?;
        }
        Request::Infer { session, sample } => {
            buf.push(REQ_INFER);
            put_u64(&mut buf, *session);
            put_sample(&mut buf, sample)?;
        }
        Request::Finalize { session } => {
            buf.push(REQ_FINALIZE);
            put_u64(&mut buf, *session);
        }
        Request::Stats => buf.push(REQ_STATS),
        Request::Traces { n } => {
            buf.push(REQ_TRACES);
            let n = u32::try_from(*n)
                .map_err(|_| WireError::Invalid(format!("trace count {n} exceeds u32")))?;
            put_u32(&mut buf, n);
        }
        Request::Events { n } => {
            buf.push(REQ_EVENTS);
            let n = u32::try_from(*n)
                .map_err(|_| WireError::Invalid(format!("event count {n} exceeds u32")))?;
            put_u32(&mut buf, n);
        }
        Request::Ping => {
            return Err(WireError::NotWire(
                "Ping is the internal readiness probe; remote peers health-check via /readyz",
            ));
        }
        Request::Shutdown => {
            return Err(WireError::NotWire(
                "Shutdown is a per-shard drain marker; stop the server with Server::shutdown",
            ));
        }
    }
    Ok(buf)
}

/// Decode one request payload. There is deliberately no tag for
/// `Shutdown` — bytes from the network can never encode it.
pub fn decode_request(payload: &[u8]) -> Result<Request, WireError> {
    let mut r = WireReader::new(payload);
    let req = match r.u8()? {
        REQ_LABELLED => Request::Labelled {
            session: r.u64()?,
            sample: r.sample()?,
        },
        REQ_INFER => Request::Infer {
            session: r.u64()?,
            sample: r.sample()?,
        },
        REQ_FINALIZE => Request::Finalize { session: r.u64()? },
        REQ_STATS => Request::Stats,
        REQ_TRACES => Request::Traces {
            n: r.u32()? as usize,
        },
        REQ_EVENTS => Request::Events {
            n: r.u32()? as usize,
        },
        tag => return Err(WireError::BadTag(tag)),
    };
    r.finish()?;
    Ok(req)
}

const RESP_ACCEPTED: u8 = 1;
const RESP_PREDICTION: u8 = 2;
const RESP_TRAINED: u8 = 3;
const RESP_OBSERVED: u8 = 4;
const RESP_ADAPTED: u8 = 5;
const RESP_STATS_TEXT: u8 = 6;
const RESP_REJECTED: u8 = 7;
const RESP_ERROR: u8 = 8;
const RESP_BYE: u8 = 9;
const RESP_TRACES: u8 = 10;
const RESP_EVENTS: u8 = 11;

/// Encode a response payload. Fallible for the same reason the zip
/// writer is: a count that does not fit its wire field is refused with
/// a typed error, never truncated.
pub fn encode_response(resp: &Response) -> Result<Vec<u8>, WireError> {
    let mut buf = Vec::new();
    match resp {
        Response::Accepted { phase, buffered } => {
            buf.push(RESP_ACCEPTED);
            put_str(&mut buf, phase)?;
            put_usize(&mut buf, *buffered);
        }
        Response::Prediction { class, scores } => {
            buf.push(RESP_PREDICTION);
            put_usize(&mut buf, *class);
            put_f32s(&mut buf, scores)?;
        }
        Response::Trained {
            p,
            q,
            beta,
            train_seconds,
        } => {
            buf.push(RESP_TRAINED);
            put_f32(&mut buf, *p);
            put_f32(&mut buf, *q);
            put_f32(&mut buf, *beta);
            put_f64(&mut buf, *train_seconds);
        }
        Response::Observed { updates, window } => {
            buf.push(RESP_OBSERVED);
            put_u64(&mut buf, *updates);
            put_usize(&mut buf, *window);
        }
        Response::Adapted {
            generation,
            p,
            q,
            updates,
        } => {
            buf.push(RESP_ADAPTED);
            put_u64(&mut buf, *generation);
            put_f32(&mut buf, *p);
            put_f32(&mut buf, *q);
            put_u64(&mut buf, *updates);
        }
        Response::StatsText(text) => {
            buf.push(RESP_STATS_TEXT);
            put_str(&mut buf, text)?;
        }
        Response::Traces(text) => {
            buf.push(RESP_TRACES);
            put_str(&mut buf, text)?;
        }
        Response::Events(text) => {
            buf.push(RESP_EVENTS);
            put_str(&mut buf, text)?;
        }
        Response::Rejected(reason) => {
            buf.push(RESP_REJECTED);
            put_str(&mut buf, reason)?;
        }
        Response::Error { kind, detail } => {
            buf.push(RESP_ERROR);
            buf.push(kind.code());
            put_str(&mut buf, detail)?;
        }
        Response::Bye => buf.push(RESP_BYE),
    }
    Ok(buf)
}

/// Decode one response payload.
pub fn decode_response(payload: &[u8]) -> Result<Response, WireError> {
    let mut r = WireReader::new(payload);
    let resp = match r.u8()? {
        RESP_ACCEPTED => {
            let phase = static_phase(&r.string()?)?;
            Response::Accepted {
                phase,
                buffered: r.usize()?,
            }
        }
        RESP_PREDICTION => Response::Prediction {
            class: r.usize()?,
            scores: r.f32s()?,
        },
        RESP_TRAINED => Response::Trained {
            p: r.f32()?,
            q: r.f32()?,
            beta: r.f32()?,
            train_seconds: r.f64()?,
        },
        RESP_OBSERVED => Response::Observed {
            updates: r.u64()?,
            window: r.usize()?,
        },
        RESP_ADAPTED => Response::Adapted {
            generation: r.u64()?,
            p: r.f32()?,
            q: r.f32()?,
            updates: r.u64()?,
        },
        RESP_STATS_TEXT => Response::StatsText(r.string()?),
        RESP_TRACES => Response::Traces(r.string()?),
        RESP_EVENTS => Response::Events(r.string()?),
        RESP_REJECTED => Response::Rejected(r.string()?),
        RESP_ERROR => {
            let code = r.u8()?;
            let kind = ErrorKind::from_code(code)
                .ok_or_else(|| WireError::Invalid(format!("unknown error-kind code {code}")))?;
            Response::Error {
                kind,
                detail: r.string()?,
            }
        }
        RESP_BYE => Response::Bye,
        tag => return Err(WireError::BadTag(tag)),
    };
    r.finish()?;
    Ok(resp)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn session_routing_key() {
        let s = Sample {
            u: vec![0.0],
            t: 1,
            label: 0,
        };
        assert_eq!(Request::Labelled { session: 7, sample: s }.session_id(), Some(7));
        assert_eq!(Request::Stats.session_id(), None);
    }

    #[test]
    fn request_roundtrips() {
        let sample = Sample {
            u: vec![0.25, -1.5, 3.0],
            t: 3,
            label: 2,
        };
        let cases = [
            Request::Labelled { session: 42, sample: sample.clone() },
            Request::Infer { session: u64::MAX, sample },
            Request::Finalize { session: 0 },
            Request::Stats,
            Request::Traces { n: 32 },
            Request::Events { n: 0 },
        ];
        for req in cases {
            let bytes = encode_request(&req).unwrap();
            assert_eq!(decode_request(&bytes).unwrap(), req);
        }
    }

    #[test]
    fn internal_markers_are_not_wire_encodable() {
        assert!(matches!(
            encode_request(&Request::Shutdown),
            Err(WireError::NotWire(_))
        ));
        assert!(matches!(
            encode_request(&Request::Ping),
            Err(WireError::NotWire(_))
        ));
        // and no tag decodes to them: the tag after Events is unknown
        assert_eq!(decode_request(&[7]), Err(WireError::BadTag(7)));
    }

    #[test]
    fn zero_t_sample_is_refused_both_ways() {
        let req = Request::Infer {
            session: 1,
            sample: Sample { u: vec![], t: 0, label: 0 },
        };
        assert!(matches!(encode_request(&req), Err(WireError::Invalid(_))));
        // hand-build the same payload: tag, session, t=0, label, empty u
        let mut raw = vec![REQ_INFER];
        put_u64(&mut raw, 1);
        put_u32(&mut raw, 0);
        put_u32(&mut raw, 0);
        put_u32(&mut raw, 0);
        assert!(matches!(decode_request(&raw), Err(WireError::Invalid(_))));
    }

    #[test]
    fn trailing_bytes_are_an_error() {
        let mut bytes = encode_request(&Request::Stats).unwrap();
        bytes.push(0xAB);
        assert_eq!(decode_request(&bytes), Err(WireError::TrailingBytes(1)));
    }

    #[test]
    fn response_roundtrips() {
        let cases = [
            Response::Accepted { phase: Phase::Collect.name(), buffered: 17 },
            Response::Prediction { class: 3, scores: vec![0.1, 0.9] },
            Response::Trained { p: 1.5, q: 0.25, beta: 0.01, train_seconds: 2.75 },
            Response::Observed { updates: 99, window: 8 },
            Response::Adapted { generation: 4, p: 1.0, q: 2.0, updates: 12 },
            Response::StatsText("a\nmultiline ☃ report".into()),
            Response::Traces("{\"trace_id\":1}\n{\"trace_id\":2}\n".into()),
            Response::Events("{\"kind\":\"shard_death\"}\n".into()),
            Response::Rejected("queue full".into()),
            Response::Error { kind: ErrorKind::NonFinite, detail: "nan".into() },
            Response::Bye,
        ];
        for resp in cases {
            let bytes = encode_response(&resp).unwrap();
            assert_eq!(decode_response(&bytes).unwrap(), resp);
        }
    }

    #[test]
    fn accepted_phase_decodes_to_the_static_name() {
        for code in 0..4u8 {
            let phase = Phase::from_code(code).unwrap().name();
            let bytes = encode_response(&Response::Accepted { phase, buffered: 0 }).unwrap();
            match decode_response(&bytes).unwrap() {
                Response::Accepted { phase: back, .. } => assert_eq!(back, phase),
                other => panic!("{other:?}"),
            }
        }
        // an unknown phase string is Invalid, not a panic
        let mut raw = vec![RESP_ACCEPTED];
        put_str(&mut raw, "warp_drive").unwrap();
        put_usize(&mut raw, 0);
        assert!(matches!(decode_response(&raw), Err(WireError::Invalid(_))));
    }

    #[test]
    fn hostile_vector_length_is_typed_not_oom() {
        // claim a 2^31-element score vector with a 5-byte payload
        let mut raw = vec![RESP_PREDICTION];
        put_u64(&mut raw, 0); // class
        put_u32(&mut raw, 1 << 31); // claimed length
        raw.push(0);
        assert!(matches!(decode_response(&raw), Err(WireError::Invalid(_))));
        // a claim under MAX_VEC but past the payload is Truncated
        let mut raw = vec![RESP_PREDICTION];
        put_u64(&mut raw, 0);
        put_u32(&mut raw, 1000);
        assert_eq!(decode_response(&raw), Err(WireError::Truncated));
    }

    #[test]
    fn empty_and_garbage_payloads_are_typed() {
        assert_eq!(decode_request(&[]), Err(WireError::Truncated));
        assert_eq!(decode_response(&[]), Err(WireError::Truncated));
        assert_eq!(decode_request(&[0xFF]), Err(WireError::BadTag(0xFF)));
        assert_eq!(decode_response(&[0x00]), Err(WireError::BadTag(0x00)));
    }
}
