//! Request/response protocol of the online edge service.

use crate::data::dataset::Sample;

/// Client-visible requests.
#[derive(Debug)]
pub enum Request {
    /// A labelled sample for online training (Collect/BpOptimize phases).
    Labelled { session: u64, sample: Sample },
    /// An unlabelled sample for inference (Serve phase).
    Infer { session: u64, sample: Sample },
    /// Force the session to finish collecting and train now.
    Finalize { session: u64 },
    /// Metrics snapshot.
    Stats,
    /// Graceful shutdown.
    Shutdown,
}

/// Responses (sent back over the per-request channel).
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Sample accepted; current phase echoed.
    Accepted { phase: &'static str, buffered: usize },
    /// Prediction with class scores.
    Prediction { class: usize, scores: Vec<f32> },
    /// Session transitioned into Serve (training finished).
    Trained {
        p: f32,
        q: f32,
        beta: f32,
        train_seconds: f64,
    },
    /// Metrics text.
    StatsText(String),
    /// Request rejected (backpressure or bad session state).
    Rejected(String),
    /// Acknowledged shutdown.
    Bye,
}

impl Request {
    pub fn session_id(&self) -> Option<u64> {
        match self {
            Request::Labelled { session, .. }
            | Request::Infer { session, .. }
            | Request::Finalize { session } => Some(*session),
            Request::Stats | Request::Shutdown => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_routing_key() {
        let s = Sample {
            u: vec![0.0],
            t: 1,
            label: 0,
        };
        assert_eq!(Request::Labelled { session: 7, sample: s }.session_id(), Some(7));
        assert_eq!(Request::Stats.session_id(), None);
    }
}
