//! Minimal blocking HTTP endpoint for observability scrapes.
//!
//! Prometheus (or `curl`) speaks a very small slice of HTTP/1.1: one
//! `GET` line, a few ignorable headers, and a close-delimited response
//! body. This module implements exactly that slice over the standard
//! library's `TcpListener` — no HTTP framework, no async runtime —
//! because the image vendors no crates and a scrape endpoint must not
//! compete with the serve path for complexity.
//!
//! Three routes:
//!
//! | route      | answer                                               |
//! |------------|------------------------------------------------------|
//! | `/metrics` | the registry in Prometheus text format 0.0.4         |
//! | `/healthz` | `200 ok` while the process is up (liveness)          |
//! | `/readyz`  | `200 ready`, or `503` + reason from [`Server::readiness`] |
//!
//! The accept loop is **serial**: one scrape is parsed, answered and
//! closed before the next is accepted. Scrape bodies are a few KB and
//! render off the registry's internal locks in microseconds, so a slow
//! or malicious client can delay other scrapers but never the serving
//! shards — read and write timeouts bound each connection to ~2 s of
//! exporter time. Liveness endpoints that can wedge the data plane are
//! worse than none.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::server::Server;
use crate::log_warn;

/// Per-connection socket budget: a scraper that cannot send one request
/// line or drain a few KB of body inside this window loses its turn.
const IO_TIMEOUT: Duration = Duration::from_millis(2000);

/// Cap on the request head we will buffer. Real scrape requests are a
/// few hundred bytes; anything larger is not Prometheus.
const MAX_HEAD: usize = 8 * 1024;

/// The observability endpoint. Owns its accept thread; dropping it (or
/// calling [`shutdown`](MetricsExporter::shutdown)) stops the loop and
/// joins the thread.
pub struct MetricsExporter {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl MetricsExporter {
    /// Bind `addr` (e.g. `"127.0.0.1:9091"`; port 0 picks a free port)
    /// and start answering scrapes against `server`'s registry.
    pub fn bind(server: Arc<Server>, addr: &str) -> io::Result<MetricsExporter> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            thread::Builder::new()
                .name("dfr-metrics-http".to_string())
                .spawn(move || accept_loop(listener, server, stop))?
        };
        Ok(MetricsExporter {
            addr,
            stop,
            accept: Some(accept),
        })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the endpoint thread. Idempotent; also
    /// run by `Drop`.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsExporter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, server: Arc<Server>, stop: Arc<AtomicBool>) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
                continue;
            }
            Err(e) => {
                log_warn!("metrics http: accept failed: {e}");
                thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        // per-connection errors are the client's problem, not ours
        let _ = serve_one(stream, &server);
    }
}

/// Read one request head, route it, write one close-delimited response.
fn serve_one(mut stream: TcpStream, server: &Server) -> io::Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let path = match read_request_path(&mut stream)? {
        Some(p) => p,
        None => {
            return respond(
                &mut stream,
                "400 Bad Request",
                "text/plain; charset=utf-8",
                "bad request\n",
            )
        }
    };
    match path.as_str() {
        "/metrics" => {
            let body = server.metrics.render_prometheus();
            respond(
                &mut stream,
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                &body,
            )
        }
        "/healthz" => respond(&mut stream, "200 OK", "text/plain; charset=utf-8", "ok\n"),
        "/readyz" => match server.readiness() {
            Ok(()) => respond(&mut stream, "200 OK", "text/plain; charset=utf-8", "ready\n"),
            Err(why) => respond(
                &mut stream,
                "503 Service Unavailable",
                "text/plain; charset=utf-8",
                &format!("not ready: {why}\n"),
            ),
        },
        _ => respond(
            &mut stream,
            "404 Not Found",
            "text/plain; charset=utf-8",
            "not found; routes: /metrics /healthz /readyz\n",
        ),
    }
}

/// Parse the request line out of the head. Returns `None` on anything
/// that is not a plausible `GET <path> HTTP/1.x` head (the caller
/// answers 400). Query strings are stripped — Prometheus appends none,
/// but humans with browsers do.
fn read_request_path(stream: &mut TcpStream) -> io::Result<Option<String>> {
    let mut head = Vec::with_capacity(512);
    let mut buf = [0u8; 512];
    loop {
        let n = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock || e.kind() == io::ErrorKind::TimedOut =>
            {
                break
            }
            Err(e) => return Err(e),
        };
        head.extend_from_slice(&buf[..n]);
        if head.len() > MAX_HEAD {
            return Ok(None);
        }
        if head.windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
        // tolerate bare-LF clients (netcat-by-hand)
        if head.windows(2).any(|w| w == b"\n\n") {
            break;
        }
    }
    let text = String::from_utf8_lossy(&head);
    let line = match text.lines().next() {
        Some(l) => l,
        None => return Ok(None),
    };
    let mut parts = line.split_whitespace();
    match (parts.next(), parts.next()) {
        (Some("GET"), Some(path)) => {
            let path = path.split('?').next().unwrap_or(path);
            Ok(Some(path.to_string()))
        }
        _ => Ok(None),
    }
}

fn respond(stream: &mut TcpStream, status: &str, content_type: &str, body: &str) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::protocol::Request;
    use crate::coordinator::server::ServerConfig;
    use crate::coordinator::session::SessionConfig;

    fn serving_pair() -> (Arc<Server>, MetricsExporter) {
        let mut scfg = SessionConfig::new(2, 2, 20);
        scfg.train.nx = 8;
        let cfg = ServerConfig {
            shards: 2,
            ..ServerConfig::new(scfg)
        };
        let server = Arc::new(Server::spawn(Box::new(NativeEngine::new(8, 2)), cfg));
        let exporter = MetricsExporter::bind(Arc::clone(&server), "127.0.0.1:0").unwrap();
        (server, exporter)
    }

    fn http_get(addr: SocketAddr, path: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        let (head, body) = raw.split_once("\r\n\r\n").unwrap();
        (head.to_string(), body.to_string())
    }

    #[test]
    fn routes_and_shutdown() {
        let (server, mut exporter) = serving_pair();
        let addr = exporter.local_addr();

        let (head, body) = http_get(addr, "/healthz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert_eq!(body, "ok\n");

        let (head, _) = http_get(addr, "/readyz");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");

        let (head, body) = http_get(addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        assert!(head.contains("version=0.0.4"), "{head}");
        assert!(body.contains("dfr_"), "no dfr_ families in:\n{body}");

        let (head, _) = http_get(addr, "/nope");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");

        exporter.shutdown();
        if let Ok(owned) = Arc::try_unwrap(server) {
            owned.shutdown();
        }
    }

    #[test]
    fn bad_request_line_is_400() {
        let (server, mut exporter) = serving_pair();
        let mut s = TcpStream::connect(exporter.local_addr()).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write!(s, "BREW /coffee HTCPCP/1.0\r\n\r\n").unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
        exporter.shutdown();
        if let Ok(owned) = Arc::try_unwrap(server) {
            owned.shutdown();
        }
    }

    #[test]
    fn metrics_reflect_served_traffic() {
        let (server, mut exporter) = serving_pair();
        let _ = server.call(Request::Stats).unwrap();
        let (_, body) = http_get(exporter.local_addr(), "/metrics");
        assert!(
            body.lines().any(|l| l.starts_with("dfr_requests_total")),
            "requests family missing:\n{body}"
        );
        assert!(
            body.lines().any(|l| l.starts_with("dfr_shards_active 2")),
            "shards_active gauge missing:\n{body}"
        );
        exporter.shutdown();
        if let Ok(owned) = Arc::try_unwrap(server) {
            owned.shutdown();
        }
    }
}
