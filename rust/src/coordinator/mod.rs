//! The online edge training & inference coordinator — the paper's system
//! contribution (§3.1) as a deployable service.
//!
//! A [`session::Session`] is one online deployment (e.g. one machine
//! under predictive maintenance). Its lifecycle is the paper's protocol:
//!
//! ```text
//! Collect ──(enough labelled samples)──► BpOptimize ──(25 epochs)──►
//! RidgeTrain ──(β sweep + in-place Cholesky)──► Serve ──(drift)──► …
//! ```
//!
//! The [`server::Server`] owns a pool of shard worker threads: requests
//! are routed to shard `session_id % shards` at submit time, enter that
//! shard's bounded queue (backpressure), and run against the shard's
//! exclusively-owned session map — no cross-shard locking. Compute runs
//! on a per-shard [`engine::Engine`] replica — either the PJRT executor
//! over the AOT artifacts (production path; Python never runs) or the
//! pure-Rust reference (tests, grid search, FPGA-sim workloads). See
//! DESIGN.md §Sharded coordinator for the routing, backpressure, and
//! shutdown protocol.

pub mod engine;
pub mod protocol;
pub mod server;
pub mod session;

pub use engine::{
    scores_from_r_tilde, Engine, FeatureRequest, NativeEngine, PjrtEngine, Recalibration,
    ReservoirUpdate,
};
pub use protocol::{Request, Response};
pub use server::{Server, ServerConfig};
pub use session::{FeedOutcome, InferError, Phase, Session, SessionConfig};
