//! The online edge training & inference coordinator — the paper's system
//! contribution (§3.1) as a deployable service.
//!
//! A [`session::Session`] is one online deployment (e.g. one machine
//! under predictive maintenance). Its lifecycle is the paper's protocol:
//!
//! ```text
//! Collect ──(enough labelled samples)──► BpOptimize ──(25 epochs)──►
//! RidgeTrain ──(β sweep + in-place Cholesky)──► Serve ──(drift)──► …
//! ```
//!
//! The [`server::Server`] owns a pool of supervised shard worker
//! threads: requests are routed to shard `session_id % shards` at submit
//! time, enter that shard's bounded queue (backpressure), and run
//! against the shard's exclusively-owned session map — no cross-shard
//! locking. Compute runs on a per-shard [`engine::Engine`] replica —
//! either the PJRT executor over the AOT artifacts (production path;
//! Python never runs) or the pure-Rust reference (tests, grid search,
//! FPGA-sim workloads). Faults are contained per request
//! (`catch_unwind` + typed [`protocol::Response::Error`]), dead shards
//! are respawned by a supervisor, and session state survives restarts
//! through [`checkpoint`] — see DESIGN.md §15 for the fault model and
//! `tests/fault_injection.rs` for the deterministic harness built on
//! [`faulty::FaultyEngine`]. Cold sessions can be parked off-heap under
//! an LRU cap / idle clock by [`hibernate`] (zipstore-backed, same
//! record format as checkpoints), and remote clients reach the whole
//! thing through the framed TCP edge in [`net`] — see DESIGN.md §16.
//!
//! Observability rides alongside the serve path without touching its
//! allocation budget: every request carries a trace id whose per-stage
//! spans land in lock-free per-shard rings ([`crate::util::trace`]),
//! operational transitions (shard deaths, generation rolls, quantizer
//! fallbacks, hibernation moves, checkpoint writes) go to a bounded
//! event journal, and [`exporter`] answers `/metrics` (Prometheus text
//! 0.0.4), `/healthz` and `/readyz` over a dependency-free HTTP
//! endpoint — see DESIGN.md §17.
//
// The serving path must never take the process down on a recoverable
// fault, so panicking escape hatches are banned module-wide outside
// tests (test modules opt back in locally).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod checkpoint;
pub mod engine;
pub mod exporter;
pub mod faulty;
pub mod hibernate;
pub mod net;
pub mod protocol;
pub mod server;
pub mod session;

pub use checkpoint::{dir_writable, CheckpointConfig, CheckpointError, ShardCheckpointer};
pub use exporter::MetricsExporter;
pub use engine::{
    features_batch_per_call, scores_from_r_tilde, scores_from_r_tilde_with, Engine,
    FeatureRequest, NativeEngine, PjrtEngine, Recalibration,
    ReservoirUpdate,
};
pub use faulty::{silence_injected_panics, FaultSpec, FaultyEngine, InjectedPanic, ShardKill};
pub use hibernate::{HibernateConfig, HibernationStore, ShardHibernator};
pub use net::{Client, ClientError, FrameError, NetConfig, NetServer};
pub use protocol::{
    decode_request, decode_response, encode_request, encode_response, ErrorKind, Request, Response,
    WireError,
};
pub use server::{CallError, Server, ServerConfig};
pub use session::{FeedOutcome, InferError, Phase, Session, SessionConfig, SessionSnapshot};
