//! Deterministic fault-injection engine for the resilience test
//! harness (`tests/fault_injection.rs`).
//!
//! [`FaultyEngine`] wraps any [`Engine`] and injects faults on a
//! PRNG-driven schedule seeded from [`FaultSpec::seed`]: the *n*-th
//! engine call of a given replica always does the same thing, so every
//! failure a test provokes is reproducible from the seed alone. Four
//! fault classes cover the coordinator's whole failure surface:
//!
//! * **panics** (`p_panic`, [`InjectedPanic`]) — exercises
//!   `catch_unwind` isolation and `Response::Error`;
//! * **typed errors** (`p_error`) — exercises the `Result` plumbing and
//!   the session's phase-restore on mid-train faults;
//! * **NaN outputs** (`p_nan` / `nan_once_at`) — exercises the
//!   non-finite quarantine;
//! * **slow calls** (`p_slow`) — exercises timeouts and the
//!   shutdown-drain deadline.
//!
//! A fifth, [`ShardKill`] (via `kill_after`/`kill_replica`), is a
//! panic the server deliberately does NOT isolate — it kills the whole
//! shard thread, which is how the supervisor respawn path is tested.
//!
//! With the all-zero [`FaultSpec::default`], the wrapper is **bitwise
//! transparent**: every call delegates unchanged, so a fault-free
//! `FaultyEngine` run is interchangeable with a bare-engine run.

use std::cell::{Cell, RefCell};
use std::panic::panic_any;
use std::time::Duration;

use anyhow::{bail, Result};

use super::engine::{Engine, Recalibration, ReservoirUpdate};
use crate::data::dataset::Sample;
use crate::dfr::mask::Mask;
use crate::runtime::executor::TrainState;
use crate::util::prng::Pcg32;

/// Panic payload for an *isolatable* injected panic: the shard loop
/// catches it, answers `Response::Error { kind: Panic, .. }`, and keeps
/// serving.
#[derive(Debug)]
pub struct InjectedPanic;

/// Panic payload the shard loop deliberately re-raises instead of
/// isolating — the whole shard thread dies, exactly like a real bug
/// escaping the per-request `catch_unwind`. Used to drive the
/// supervisor's detect → respawn → rehydrate path.
#[derive(Debug)]
pub struct ShardKill;

/// Deterministic fault schedule. Probabilities are per engine call and
/// evaluated from ONE uniform draw against cumulative edges in the
/// order panic → error → NaN → slow, so at most one probabilistic fault
/// fires per call.
#[derive(Clone, Debug, Default)]
pub struct FaultSpec {
    /// PRNG seed; each replica derives its own stream from (seed,
    /// replica number), so schedules are independent but reproducible
    pub seed: u64,
    /// probability of an isolatable [`InjectedPanic`]
    pub p_panic: f32,
    /// probability of a typed `Err` return
    pub p_error: f32,
    /// probability of a NaN-filled output (feature/infer paths only;
    /// train/recalibrate calls draw but ignore a NaN verdict)
    pub p_nan: f32,
    /// probability of sleeping [`slow`](Self::slow) before answering
    pub p_slow: f32,
    /// injected latency for slow calls
    pub slow: Duration,
    /// kill the owning shard thread ([`ShardKill`]) on exactly this
    /// call number (1-based) of the matching replica
    pub kill_after: Option<u64>,
    /// restrict `kill_after` to one replica number (see
    /// [`FaultyEngine::replica`]); `None` = any replica
    pub kill_replica: Option<u64>,
    /// emit exactly one NaN output on this call number (1-based) —
    /// deterministic placement for the quarantine test, independent of
    /// the probabilistic schedule
    pub nan_once_at: Option<u64>,
}

/// What one schedule evaluation decided (beyond panics, which unwind).
enum Verdict {
    Clean,
    Nan,
}

/// An [`Engine`] wrapper that injects faults per [`FaultSpec`].
///
/// Replica numbering: the engine the server is constructed with is
/// replica 0; each [`fork`](Engine::fork) derives child number
/// `parent * 8 + nth_child` (nth is 1-based). The numbering is stable
/// across runs, so `kill_replica` can target e.g. "the original shard-1
/// replica" while letting its respawned successor run clean.
pub struct FaultyEngine {
    inner: Box<dyn Engine>,
    spec: FaultSpec,
    rng: RefCell<Pcg32>,
    calls: Cell<u64>,
    forks: Cell<u64>,
    replica: u64,
}

/// Install a process-wide panic hook that stays silent for
/// [`InjectedPanic`] / [`ShardKill`] payloads and delegates everything
/// else to the previous hook. Idempotent; call from any test that
/// provokes injected panics so expected unwinds don't spam stderr while
/// real panics keep their backtraces.
pub fn silence_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected =
                info.payload().is::<InjectedPanic>() || info.payload().is::<ShardKill>();
            if !injected {
                prev(info);
            }
        }));
    });
}

impl FaultyEngine {
    pub fn new(inner: Box<dyn Engine>, spec: FaultSpec) -> Self {
        let rng = Pcg32::new(spec.seed, 0);
        FaultyEngine {
            inner,
            spec,
            rng: RefCell::new(rng),
            calls: Cell::new(0),
            forks: Cell::new(0),
            replica: 0,
        }
    }

    /// This replica's number in the fork tree (root = 0).
    pub fn replica(&self) -> u64 {
        self.replica
    }

    /// Engine calls this replica has served so far.
    pub fn calls(&self) -> u64 {
        self.calls.get()
    }

    /// Evaluate the fault schedule for one engine call. May panic
    /// ([`InjectedPanic`] / [`ShardKill`]), return `Err` (injected
    /// engine error), sleep, or demand a NaN output.
    fn trip(&self) -> Result<Verdict> {
        let n = self.calls.get() + 1;
        self.calls.set(n);
        if let Some(k) = self.spec.kill_after {
            let replica_matches = self.spec.kill_replica.map_or(true, |r| r == self.replica);
            if n == k && replica_matches {
                panic_any(ShardKill);
            }
        }
        if self.spec.nan_once_at == Some(n) {
            return Ok(Verdict::Nan);
        }
        let u = self.rng.borrow_mut().uniform();
        let mut edge = self.spec.p_panic;
        if u < edge {
            panic_any(InjectedPanic);
        }
        edge += self.spec.p_error;
        if u < edge {
            bail!("injected engine error (replica {}, call {n})", self.replica);
        }
        edge += self.spec.p_nan;
        if u < edge {
            return Ok(Verdict::Nan);
        }
        edge += self.spec.p_slow;
        if u < edge {
            std::thread::sleep(self.spec.slow);
        }
        Ok(Verdict::Clean)
    }
}

impl Engine for FaultyEngine {
    fn train_step(
        &self,
        s: &Sample,
        mask: &Mask,
        state: &mut TrainState,
        lr_res: f32,
        lr_out: f32,
    ) -> Result<f32> {
        // a NaN verdict is ignored here: NaN injection targets the
        // feature/score outputs the quarantine inspects
        let _ = self.trip()?;
        self.inner.train_step(s, mask, state, lr_res, lr_out)
    }

    fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
        match self.trip()? {
            Verdict::Clean => self.inner.features(s, mask, p, q),
            Verdict::Nan => {
                let f = self.inner.features(s, mask, p, q)?;
                Ok(vec![f32::NAN; f.len()])
            }
        }
    }

    fn features_into(
        &self,
        s: &Sample,
        mask: &Mask,
        p: f32,
        q: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        match self.trip()? {
            Verdict::Clean => self.inner.features_into(s, mask, p, q, out),
            Verdict::Nan => {
                self.inner.features_into(s, mask, p, q, out)?;
                out.iter_mut().for_each(|x| *x = f32::NAN);
                Ok(())
            }
        }
    }

    // features_batch_into deliberately NOT overridden: the default loops
    // features_into, so each lane of a batch trips the schedule
    // individually — batched and per-call runs see the same per-lane
    // fault sequence.

    fn scores_from_features_exact(&self) -> bool {
        self.inner.scores_from_features_exact()
    }

    fn kernels(&self) -> crate::simd::Kernels {
        self.inner.kernels()
    }

    fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w_tilde: &[f32]) -> Result<Vec<f32>> {
        match self.trip()? {
            Verdict::Clean => self.inner.infer(s, mask, p, q, w_tilde),
            Verdict::Nan => {
                let z = self.inner.infer(s, mask, p, q, w_tilde)?;
                Ok(vec![f32::NAN; z.len()])
            }
        }
    }

    fn name(&self) -> &'static str {
        "faulty"
    }

    fn generation(&self) -> u64 {
        self.inner.generation()
    }

    fn recalibrate(&self, upd: &ReservoirUpdate) -> Result<Recalibration> {
        let _ = self.trip()?;
        self.inner.recalibrate(upd)
    }

    fn fork(&self) -> Option<Box<dyn Engine>> {
        let inner = self.inner.fork()?;
        let nth = self.forks.get() + 1;
        self.forks.set(nth);
        let child = self.replica * 8 + nth;
        Some(Box::new(FaultyEngine {
            inner,
            spec: self.spec.clone(),
            rng: RefCell::new(Pcg32::new(self.spec.seed, child)),
            calls: Cell::new(0),
            forks: Cell::new(0),
            replica: child,
        }))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;

    fn sample() -> Sample {
        Sample {
            u: vec![0.3, -0.2, 0.5, 0.1],
            t: 2,
            label: 0,
        }
    }

    #[test]
    fn zero_fault_spec_is_transparent() {
        let nx = 6;
        let eng = NativeEngine::new(nx, 2);
        let faulty = FaultyEngine::new(Box::new(NativeEngine::new(nx, 2)), FaultSpec::default());
        let mut rng = Pcg32::seed(1);
        let mask = Mask::random(nx, 2, &mut rng);
        let s = sample();
        let a = eng.features(&s, &mask, 0.5, 0.1).unwrap();
        let b = faulty.features(&s, &mask, 0.5, 0.1).unwrap();
        assert_eq!(a, b, "fault-free wrapper must be bitwise transparent");
        assert_eq!(faulty.calls(), 1);
    }

    #[test]
    fn error_schedule_is_deterministic() {
        let spec = FaultSpec {
            seed: 42,
            p_error: 0.3,
            ..FaultSpec::default()
        };
        let run = || {
            let faulty = FaultyEngine::new(Box::new(NativeEngine::new(6, 2)), spec.clone());
            let mut rng = Pcg32::seed(1);
            let mask = Mask::random(6, 2, &mut rng);
            let s = sample();
            (0..64)
                .map(|_| faulty.features(&s, &mask, 0.5, 0.1).is_err())
                .collect::<Vec<bool>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed must give the same fault schedule");
        assert!(a.iter().any(|&e| e), "p=0.3 over 64 calls must err");
        assert!(!a.iter().all(|&e| e), "p=0.3 over 64 calls must also succeed");
    }

    #[test]
    fn nan_once_at_fires_exactly_once() {
        let spec = FaultSpec {
            seed: 7,
            nan_once_at: Some(3),
            ..FaultSpec::default()
        };
        let faulty = FaultyEngine::new(Box::new(NativeEngine::new(6, 2)), spec);
        let mut rng = Pcg32::seed(1);
        let mask = Mask::random(6, 2, &mut rng);
        let s = sample();
        for call in 1..=6u64 {
            let f = faulty.features(&s, &mask, 0.5, 0.1).unwrap();
            let nan = f.iter().any(|x| x.is_nan());
            assert_eq!(nan, call == 3, "call {call}");
        }
    }

    #[test]
    fn fork_numbering_is_stable() {
        let root = FaultyEngine::new(
            Box::new(NativeEngine::new(6, 2)),
            FaultSpec {
                seed: 9,
                ..FaultSpec::default()
            },
        );
        assert_eq!(root.replica(), 0);
        assert!(root.fork().is_some());
        assert!(root.fork().is_some());
        assert_eq!(root.forks.get(), 2);
        // kill targeting proves the child numbers: only replica 1 (the
        // first fork of root) dies on its first call
        let spec = FaultSpec {
            seed: 9,
            kill_after: Some(1),
            kill_replica: Some(1),
            ..FaultSpec::default()
        };
        let root = FaultyEngine::new(Box::new(NativeEngine::new(6, 2)), spec);
        let child1 = root.fork().unwrap();
        let child2 = root.fork().unwrap();
        let mut rng = Pcg32::seed(1);
        let mask = Mask::random(6, 2, &mut rng);
        let s = sample();
        assert!(child2.features(&s, &mask, 0.5, 0.1).is_ok());
        assert!(root.features(&s, &mask, 0.5, 0.1).is_ok());
        silence_injected_panics();
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = child1.features(&s, &mask, 0.5, 0.1);
        }))
        .unwrap_err();
        assert!(payload.is::<ShardKill>());
    }

    #[test]
    fn kill_after_panics_with_shard_kill_payload() {
        silence_injected_panics();
        let spec = FaultSpec {
            seed: 1,
            kill_after: Some(2),
            ..FaultSpec::default()
        };
        let faulty = FaultyEngine::new(Box::new(NativeEngine::new(6, 2)), spec);
        let mut rng = Pcg32::seed(1);
        let mask = Mask::random(6, 2, &mut rng);
        let s = sample();
        assert!(faulty.features(&s, &mask, 0.5, 0.1).is_ok());
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = faulty.features(&s, &mask, 0.5, 0.1);
        }))
        .unwrap_err();
        assert!(payload.is::<ShardKill>());
    }
}
