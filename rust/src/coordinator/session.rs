//! Per-deployment session: the paper's online protocol as a state
//! machine over an [`Engine`].
//!
//! ```text
//! Collect ─► BpOptimize ─► RidgeTrain ─► Serve
//! ```
//!
//! * **Collect** buffers labelled samples up to `collect_target` (bounded
//!   — edge memory budget).
//! * **BpOptimize** runs the §4.1 SGD protocol over the buffer via
//!   `Engine::train_step` (per-sample = true online SGD), with the LR
//!   decay schedule.
//! * **RidgeTrain** streams r̃ through the packed accumulator and solves
//!   with the in-place 1-D Cholesky per β, selecting by held-out loss.
//! * **Serve** answers inference requests. Labelled samples arriving in
//!   Serve adapt the model to drift by one of two paths:
//!   - **streaming** (when `TrainConfig::forgetting` or `::window` is
//!     set): each sample rank-1-updates the packed Cholesky factor and
//!     re-solves the output layer in place — O(s²) per sample, zero
//!     allocations, answered with `Observed` (the session never leaves
//!     Serve). A rolling-error fallback can still force the full batch
//!     pipeline when the online model stops tracking.
//!   - **batch** (otherwise): samples are buffered and `retrain_after`
//!     triggers the full §4.1 pipeline again.
//!
//! # Online reservoir adaptation (DESIGN.md §13)
//!
//! With [`SessionConfig::adapt_reservoir`] set (and the streaming ridge
//! active), labelled Serve samples additionally drive the truncated-BPTT
//! reservoir optimizer through `Engine::train_step` at
//! [`adapt_lr`](SessionConfig::adapt_lr) — the paper's Phase-1 SGD,
//! per-sample, without leaving Serve. The optimizer advances a
//! *candidate* (p, q) in `TrainState` while serving stays pinned to the
//! **generation** parameters `(gen_p, gen_q)` the ridge factor was
//! seeded at; features and factor therefore never mix reservoir
//! generations. When the accumulated drift `|Δp| + |Δq|` crosses
//! [`adapt_drift_eps`](SessionConfig::adapt_drift_eps), the session
//! notifies the engine (`Engine::recalibrate` — quantized backends
//! re-run their error budget and may fall back to f32), re-featurizes
//! its bounded ring buffer through the updated reservoir, reseeds the
//! online ridge from it, and answers `Adapted` with the new generation.
//! A generation mismatch against [`Engine::generation`] (e.g. another
//! session on the shard flipped a shared quantized datapath) forces the
//! same reseed before anything else is folded.
//!
//! A `Session` is single-threaded by design: the server routes all
//! requests for one session id to the same shard thread, which owns the
//! session exclusively — no locking appears anywhere in this module.

use std::collections::VecDeque;
use std::fmt;

use anyhow::Result;

use super::engine::{scores_from_r_tilde_with, Engine, ReservoirUpdate};
use crate::data::dataset::Sample;
use crate::dfr::mask::Mask;
use crate::dfr::train::{online_ridge_from_features, ridge_phase_from_features, TrainConfig};
use crate::linalg::ridge::{OnlineRidge, OnlineRidgeState, RidgeSolution};
use crate::runtime::executor::TrainState;
use crate::util::prng::Pcg32;
use crate::util::trace::{self, Stage};

/// Session lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Collect,
    BpOptimize,
    RidgeTrain,
    Serve,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Collect => "collect",
            Phase::BpOptimize => "bp_optimize",
            Phase::RidgeTrain => "ridge_train",
            Phase::Serve => "serve",
        }
    }

    /// Stable wire code for the checkpoint codec.
    pub fn code(self) -> u8 {
        match self {
            Phase::Collect => 0,
            Phase::BpOptimize => 1,
            Phase::RidgeTrain => 2,
            Phase::Serve => 3,
        }
    }

    /// Inverse of [`code`](Self::code); `None` for unknown bytes (a
    /// corrupt or future-version checkpoint).
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(Phase::Collect),
            1 => Some(Phase::BpOptimize),
            2 => Some(Phase::RidgeTrain),
            3 => Some(Phase::Serve),
            _ => None,
        }
    }
}

/// Session knobs.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// labelled samples to buffer before training starts
    pub collect_target: usize,
    /// hard cap on the buffer (backpressure boundary)
    pub buffer_cap: usize,
    /// the §4.1 protocol parameters
    pub train: TrainConfig,
    /// classes
    pub n_c: usize,
    /// input channels
    pub n_v: usize,
    /// retrain after this many new labelled samples arrive in Serve
    /// (None = never). Ignored while the streaming path is active
    /// (`train.forgetting` / `train.window`) — there every labelled
    /// sample already updates the model.
    pub retrain_after: Option<usize>,
    /// Streaming-path fallback: when the rolling error rate of the
    /// online model over the last [`fallback_window`](Self::fallback_window)
    /// labelled samples exceeds this, the session runs the full batch
    /// pipeline over its recent-sample buffer (`None` = never fall
    /// back). The error is *prequential* — each sample is scored by the
    /// model **before** it updates it, so the estimate is honest.
    pub fallback_error_rate: Option<f32>,
    /// size of the rolling error window (also the minimum number of
    /// streamed samples before the fallback can trigger)
    pub fallback_window: usize,
    /// Serve-phase reservoir adaptation: labelled samples also drive
    /// truncated-BPTT SGD steps on (p, q) through `Engine::train_step`.
    /// Effective only while the streaming ridge is active
    /// (`train.forgetting` / `train.window`) — the re-featurization
    /// reseed needs the online factor and the bounded sample ring.
    pub adapt_reservoir: bool,
    /// learning rate of the serve-loop reservoir SGD steps (applied to
    /// both the reservoir and the SGD output-layer state)
    pub adapt_lr: f32,
    /// accumulated candidate drift `|p − gen_p| + |q − gen_q|` that
    /// triggers recalibration + re-featurization into a new generation
    pub adapt_drift_eps: f32,
}

impl SessionConfig {
    pub fn new(n_v: usize, n_c: usize, collect_target: usize) -> Self {
        SessionConfig {
            collect_target,
            buffer_cap: collect_target * 2,
            train: TrainConfig::default(),
            n_c,
            n_v,
            retrain_after: None,
            fallback_error_rate: None,
            fallback_window: 32,
            adapt_reservoir: false,
            adapt_lr: 0.01,
            adapt_drift_eps: 0.02,
        }
    }
}

/// Result of feeding a sample.
#[derive(Debug, PartialEq)]
pub enum FeedOutcome {
    Buffered(usize),
    /// training ran and the session is now serving
    Trained {
        p: f32,
        q: f32,
        beta: f32,
        train_seconds: f64,
    },
    /// Serve-phase streaming update applied: the output layer was
    /// rank-1-updated and re-solved in place (no retrain, no phase
    /// change). `updates` is the accumulator's lifetime fold count,
    /// `window` its current occupancy. `reservoir_step` reports whether
    /// the sample also drove a reservoir-parameter SGD step
    /// (`SessionConfig::adapt_reservoir`).
    Observed {
        updates: u64,
        window: usize,
        reservoir_step: bool,
    },
    /// Serve-phase reservoir adaptation rolled a new generation — the
    /// accumulated (p, q) drift crossed the threshold, or the engine's
    /// datapath generation moved under the session. The engine
    /// recalibrated, the ring buffer was re-featurized through the
    /// updated reservoir at the new `(p, q)`, and the online ridge was
    /// reseeded from it. `generation` is the session's new reservoir
    /// generation, `updates` the number of buffered samples re-folded
    /// into the fresh factor; `reservoir_step` reports whether this feed
    /// also drove a reservoir-parameter SGD step.
    Adapted {
        generation: u64,
        p: f32,
        q: f32,
        updates: u64,
        reservoir_step: bool,
    },
    Rejected(String),
}

/// Why [`Session::infer`] refused — the flattened replacement for the
/// old nested `Result<Result<_, String>>`.
#[derive(Debug)]
pub enum InferError {
    /// the session has not reached (or has left) the Serve phase
    NotServing { session: u64, phase: Phase },
    /// the compute backend failed
    Engine(anyhow::Error),
}

impl fmt::Display for InferError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InferError::NotServing { session, phase } => {
                write!(f, "session {} not serving (phase {})", session, phase.name())
            }
            InferError::Engine(e) => write!(f, "engine error: {e:#}"),
        }
    }
}

impl std::error::Error for InferError {}

/// One online deployment.
pub struct Session {
    pub id: u64,
    pub cfg: SessionConfig,
    pub phase: Phase,
    pub mask: Mask,
    /// labelled-sample buffer: append-only during Collect, bounded FIFO
    /// (O(1) pop_front) on the streaming Serve path
    buffer: VecDeque<Sample>,
    new_since_train: usize,
    state: TrainState,
    solution: Option<RidgeSolution>,
    /// Serve-phase streaming accumulator (present iff the config enables
    /// forgetting/window); reseeded by every batch train
    online: Option<OnlineRidge>,
    /// reusable r̃ buffer for the streaming path (zero-alloc steady state)
    feat_scratch: Vec<f32>,
    /// rolling prequential-error ring for the batch fallback
    err_ring: Vec<bool>,
    err_head: usize,
    err_len: usize,
    err_count: usize,
    rng: Pcg32,
    /// mean SGD loss per epoch of the last training run
    pub epoch_losses: Vec<f32>,
    /// reservoir generation of the served model: advanced by every batch
    /// train and every adaptation reseed. The online ridge factor, the
    /// served `W̃`, and the features folded into them all belong to this
    /// generation — never to a newer candidate.
    generation: u64,
    /// `Engine::generation` observed when the current factor was seeded;
    /// a mismatch on a later feed means the shared datapath changed and
    /// forces a reseed before anything else is folded
    engine_generation: u64,
    /// reservoir parameters of the served generation — what features and
    /// inference use while the candidate `state.(p, q)` drifts ahead
    gen_p: f32,
    gen_q: f32,
    /// workload envelope for engine recalibration (longest series /
    /// largest |u| seen by this session)
    obs_t_max: usize,
    obs_u_max: f32,
    /// set when a fault hit this session (caught panic, engine error,
    /// non-finite quarantine); cleared by the recovery retrain the next
    /// labelled Serve sample triggers
    degraded: bool,
    /// lifetime count of non-finite values quarantined on this session
    quarantines: u64,
    /// lifetime count of state-mutating requests (labelled feeds /
    /// finalizes) applied — the checkpoint freshness stamp: when two
    /// snapshot files carry the same session id, the higher `mutations`
    /// wins on restore
    mutations: u64,
}

impl Session {
    pub fn new(id: u64, mut cfg: SessionConfig, seed: u64) -> Self {
        // an adaptation reseed rebuilds the ridge factor from the sample
        // ring: a window wider than the ring would silently shrink the
        // effective training set on every generation roll, so the ring
        // is grown to back a full-window refold
        if cfg.adapt_reservoir {
            if let Some(w) = cfg.train.window {
                cfg.buffer_cap = cfg.buffer_cap.max(w);
            }
        }
        let mut rng = Pcg32::new(seed, id);
        let mask = Mask::random(cfg.train.nx, cfg.n_v, &mut rng);
        let state = TrainState::init(cfg.n_c, cfg.train.nx, cfg.train.p_init, cfg.train.q_init);
        let err_ring = vec![false; cfg.fallback_window];
        let (gen_p, gen_q) = (cfg.train.p_init, cfg.train.q_init);
        Session {
            id,
            cfg,
            phase: Phase::Collect,
            mask,
            buffer: VecDeque::new(),
            new_since_train: 0,
            state,
            solution: None,
            online: None,
            feat_scratch: Vec::new(),
            err_ring,
            err_head: 0,
            err_len: 0,
            err_count: 0,
            rng,
            epoch_losses: Vec::new(),
            generation: 0,
            engine_generation: 0,
            gen_p,
            gen_q,
            obs_t_max: 0,
            obs_u_max: 0.0,
            degraded: false,
            quarantines: 0,
            mutations: 0,
        }
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    pub fn solution(&self) -> Option<&RidgeSolution> {
        self.solution.as_ref()
    }

    /// The Serve-phase streaming accumulator, when active.
    pub fn online(&self) -> Option<&OnlineRidge> {
        self.online.as_ref()
    }

    /// Candidate reservoir parameters — where the (possibly streaming)
    /// optimizer currently is. Equals [`serving_params`](Self::serving_params)
    /// except mid-adaptation, between reseeds.
    pub fn params(&self) -> (f32, f32) {
        (self.state.p, self.state.q)
    }

    /// Reservoir parameters of the **served** generation: what features
    /// for the online ridge and `infer` are extracted with.
    pub fn serving_params(&self) -> (f32, f32) {
        (self.gen_p, self.gen_q)
    }

    /// The session's reservoir generation (advanced by every batch train
    /// and every adaptation reseed).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The engine datapath generation the current factor was seeded
    /// under. The server's batch planner compares this against
    /// `Engine::generation()` — a mismatch means the per-call path would
    /// reseed (and answer `Adapted`), so the request must NOT be batched
    /// or that response would silently degrade to `Observed`.
    pub fn engine_generation(&self) -> u64 {
        self.engine_generation
    }

    /// Mark the session as having been hit by a fault (caught panic,
    /// engine error, non-finite score). The next labelled Serve sample
    /// runs the batch-fallback retrain, which rebuilds every derived
    /// structure (factor, W̃, error ring) from the raw sample buffer.
    ///
    /// A *panic* can unwind out of mid-train, skipping [`train`]'s
    /// error-path phase restore and stranding the phase in
    /// `BpOptimize`/`RidgeTrain` — states from which no feed can ever
    /// trigger training again. Flagging rolls such a phase back to the
    /// nearest stable one (Serve if a solution is already served,
    /// Collect otherwise) so the recovery retrain can actually fire.
    pub fn flag_degraded(&mut self) {
        self.degraded = true;
        if matches!(self.phase, Phase::BpOptimize | Phase::RidgeTrain) {
            self.phase = if self.solution.is_some() {
                Phase::Serve
            } else {
                Phase::Collect
            };
        }
    }

    /// Whether the session is flagged degraded (pending recovery).
    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Lifetime count of non-finite values quarantined on this session.
    pub fn quarantine_events(&self) -> u64 {
        self.quarantines
    }

    /// Lifetime count of state-mutating requests applied — the
    /// checkpoint freshness stamp (highest wins on restore).
    pub fn mutations(&self) -> u64 {
        self.mutations
    }

    /// Whether labelled feeds currently take the streaming Serve path
    /// (the only Feed path whose feature extraction is batchable: it
    /// folds exactly one r̃ at the served `(gen_p, gen_q)`).
    pub fn streaming_serve(&self) -> bool {
        self.phase == Phase::Serve && self.online.is_some()
    }

    /// The validation `feed_labelled` applies before touching the
    /// engine. The batch planner must skip invalid samples (they are
    /// answered `Rejected` without a forward pass — pre-extracting
    /// features for them would change behavior).
    pub fn sample_valid(&self, sample: &Sample) -> bool {
        sample.label < self.cfg.n_c
            && sample.v() == self.cfg.n_v
            && sample.u.iter().all(|u| u.is_finite())
    }

    /// Whether the batch planner may pre-extract features for a labelled
    /// feed on this session: everything [`sample_valid`](Self::sample_valid)
    /// checks, plus no pending degraded-recovery retrain (which the
    /// per-call path runs before folding — batching would skip it).
    pub fn batchable(&self) -> bool {
        !self.degraded
    }

    fn push_err(&mut self, is_err: bool) {
        let cap = self.err_ring.len();
        if cap == 0 {
            return;
        }
        if self.err_len == cap {
            self.err_count -= self.err_ring[self.err_head] as usize;
            self.err_ring[self.err_head] = is_err;
            self.err_head = (self.err_head + 1) % cap;
        } else {
            self.err_ring[(self.err_head + self.err_len) % cap] = is_err;
            self.err_len += 1;
        }
        self.err_count += is_err as usize;
    }

    fn reset_err(&mut self) {
        self.err_head = 0;
        self.err_len = 0;
        self.err_count = 0;
    }

    /// Input validation shared by both labelled-feed entry points —
    /// `Some(Rejected)` means the sample never touches the engine (the
    /// batch planner mirrors this via [`sample_valid`](Self::sample_valid)).
    fn validate(&self, sample: &Sample) -> Option<FeedOutcome> {
        if sample.label >= self.cfg.n_c {
            return Some(FeedOutcome::Rejected(format!(
                "label {} out of range ({})",
                sample.label, self.cfg.n_c
            )));
        }
        if sample.v() != self.cfg.n_v {
            return Some(FeedOutcome::Rejected(format!(
                "channel count {} != {}",
                sample.v(),
                self.cfg.n_v
            )));
        }
        // non-finite inputs are rejected at the door: folding a NaN into
        // the Gram shadow would poison the factor permanently
        if !sample.u.iter().all(|u| u.is_finite()) {
            return Some(FeedOutcome::Rejected("non-finite input sample".into()));
        }
        None
    }

    /// Feed one labelled sample. May trigger the full training pipeline.
    pub fn feed_labelled(&mut self, engine: &dyn Engine, sample: Sample) -> Result<FeedOutcome> {
        if let Some(rej) = self.validate(&sample) {
            return Ok(rej);
        }
        self.mutations += 1;
        // degraded recovery: a fault (caught panic / engine error /
        // non-finite quarantine) flagged this session — rebuild every
        // derived structure from the raw sample ring via the batch
        // pipeline before trusting the streaming factor again. Also
        // covers a Collect-phase session whose first training was killed
        // by a panic: once the buffer holds a training set, every
        // further feed retries the train (bounded by the FIFO pop, the
        // buffer can never wedge at `buffer_cap`).
        if self.degraded
            && !self.buffer.is_empty()
            && (self.phase == Phase::Serve
                || self.buffer.len() + 1 >= self.cfg.collect_target)
        {
            self.degraded = false;
            if self.buffer.len() >= self.cfg.buffer_cap {
                self.buffer.pop_front();
            }
            self.buffer.push_back(sample);
            return self.train(engine);
        }
        // streaming Serve path: O(s²) in-place adaptation, no buffering
        // backpressure (the recent-sample buffer is a bounded FIFO there)
        if self.phase == Phase::Serve && self.online.is_some() {
            return self.observe_online(engine, sample);
        }
        if self.buffer.len() >= self.cfg.buffer_cap {
            return Ok(FeedOutcome::Rejected("buffer full (backpressure)".into()));
        }
        self.buffer.push_back(sample);
        self.new_since_train += 1;

        let should_train = match self.phase {
            Phase::Collect => self.buffer.len() >= self.cfg.collect_target,
            Phase::Serve => self
                .cfg
                .retrain_after
                .is_some_and(|n| self.new_since_train >= n),
            _ => false,
        };
        if should_train {
            let t = self.train(engine)?;
            return Ok(t);
        }
        Ok(FeedOutcome::Buffered(self.buffer.len()))
    }

    /// The Serve-phase streaming update: extract r̃ into the session
    /// scratch **at the served generation's (p, q)**, score the sample
    /// against the pre-update model (prequential error, feeds the
    /// fallback trigger), fold it into the online accumulator, and
    /// refresh the served `W̃_out` in place. With adaptation enabled the
    /// sample then also drives one truncated-BPTT SGD step on the
    /// candidate (p, q); crossing the drift threshold recalibrates the
    /// engine and reseeds a new generation (`Adapted`). Zero heap
    /// allocations in steady state (`tests/zero_alloc.rs`); the reseed
    /// path allocates, but only on generation changes.
    fn observe_online(&mut self, engine: &dyn Engine, sample: Sample) -> Result<FeedOutcome> {
        // a shared-datapath change since the factor was seeded (another
        // session recalibrated a quantized engine on this shard) would
        // mix reservoir generations — reseed before folding anything;
        // the incoming sample still folds below, into the fresh factor,
        // and the feed is answered `Adapted`
        // (if this feed's own BPTT step below also crosses the drift
        // threshold, a second reseed follows at the candidate params —
        // a rare double roll, accepted: the first reseed is what makes
        // folding and prequential-scoring this sample coherent at all.
        // The feed is answered with the second roll's Adapted, so the
        // generation skips a value and refeaturize_total counts one —
        // a deliberate, bounded undercount on this corner)
        let mut datapath_refold: Option<u64> = None;
        if engine.generation() != self.engine_generation {
            // re-featurize at the CURRENT serving params (they were
            // budget-validated at the last roll; the candidate's drift
            // keeps accumulating toward its own recalibrated roll)
            let _span = trace::span(Stage::OnlineRidge);
            datapath_refold = Some(self.reseed_online(engine, false)?);
        }
        {
            let _span = trace::span(Stage::ScoreFold);
            engine.features_into(
                &sample,
                &self.mask,
                self.gen_p,
                self.gen_q,
                &mut self.feat_scratch,
            )?;
        }
        self.fold_observation(engine, sample, datapath_refold)
    }

    /// Feed one labelled sample whose r̃ was already extracted by the
    /// server's batched planner ([`Engine::features_batch_into`]) — the
    /// streaming-Serve fold without the per-call forward pass.
    ///
    /// The caller (the shard drain loop) owns the preconditions: the
    /// session is on the streaming Serve path, the sample passed
    /// [`sample_valid`](Self::sample_valid), and `features` were
    /// extracted at this session's current `(mask, gen_p, gen_q)` under
    /// the engine's **current** datapath generation. A mid-batch
    /// generation roll invalidates planned features; the server re-plans
    /// those requests through [`feed_labelled`](Self::feed_labelled)
    /// instead (the batch-split regression in
    /// `tests/batch_equivalence.rs`). The asserts here are the last line
    /// of defense against cross-generation feature mixing.
    pub fn feed_labelled_with_features(
        &mut self,
        engine: &dyn Engine,
        sample: Sample,
        features: &[f32],
    ) -> Result<FeedOutcome> {
        if let Some(rej) = self.validate(&sample) {
            return Ok(rej);
        }
        self.mutations += 1;
        assert!(
            self.streaming_serve(),
            "batched feed requires the streaming Serve path"
        );
        assert_eq!(
            engine.generation(),
            self.engine_generation,
            "stale batched features: the engine datapath moved after planning"
        );
        // the fold tail reads r̃ from the session scratch — copy in
        // (capacity reused; no steady-state allocation)
        self.feat_scratch.clear();
        self.feat_scratch.extend_from_slice(features);
        self.fold_observation(engine, sample, None)
    }

    /// The tail of a streaming-Serve feed, shared by the per-call and
    /// batched entry points: `self.feat_scratch` already holds r̃ of
    /// `sample` at the served generation. Scores prequentially, folds,
    /// refreshes W̃, then runs the adaptation step / fallback triggers.
    fn fold_observation(
        &mut self,
        engine: &dyn Engine,
        sample: Sample,
        datapath_refold: Option<u64>,
    ) -> Result<FeedOutcome> {
        // non-finite quarantine: a NaN/Inf r̃ must never reach the Gram
        // shadow (one poisoned fold corrupts the factor for good). Keep
        // the raw sample — its bits are finite-checked at the door — and
        // recover through the batch pipeline, which re-extracts every
        // feature from scratch.
        if !self.feat_scratch.iter().all(|f| f.is_finite()) {
            self.quarantines += 1;
            self.degraded = false;
            if !self.buffer.is_empty() && self.buffer.len() >= self.cfg.buffer_cap {
                self.buffer.pop_front();
            }
            self.buffer.push_back(sample);
            return self.train(engine);
        }
        // the rank-1 fold, W̃ refresh and adaptation step below are one
        // OnlineRidge span; the guard is dropped before the batch-retrain
        // fallback so `train`'s own span does not double-count the period
        let span = trace::span(Stage::OnlineRidge);
        let Some(online) = self.online.as_mut() else {
            return Ok(FeedOutcome::Rejected(
                "internal: streaming fold without an online factor".into(),
            ));
        };
        let mispredicted = online.predict_class(&self.feat_scratch) != sample.label;
        let stats = online.observe(&self.feat_scratch, sample.label);
        self.push_err(mispredicted);
        if let (Some(sol), Some(online)) = (self.solution.as_mut(), self.online.as_ref()) {
            sol.w_tilde.copy_from_slice(online.w_tilde());
        }
        // keep a bounded FIFO of recent labelled samples so the batch
        // fallback (and the adaptation reseed) has something to work on
        if !self.buffer.is_empty() && self.buffer.len() >= self.cfg.buffer_cap {
            self.buffer.pop_front();
        }
        self.buffer.push_back(sample);
        let Some(sample) = self.buffer.back() else {
            return Ok(FeedOutcome::Rejected("internal: empty ring after push".into()));
        };
        self.new_since_train += 1;

        // streaming reservoir adaptation: one truncated-BPTT SGD step on
        // the candidate (p, q) — serving stays on (gen_p, gen_q) until
        // the drift threshold rolls the generation forward
        let mut reservoir_step = false;
        if self.cfg.adapt_reservoir {
            self.obs_t_max = self.obs_t_max.max(sample.t);
            for &u in &sample.u {
                self.obs_u_max = self.obs_u_max.max(u.abs());
            }
            let lr = self.cfg.adapt_lr;
            engine.train_step(sample, &self.mask, &mut self.state, lr, lr)?;
            if self.cfg.train.project_to_search_range {
                crate::dfr::grid::project_to_search_range(&mut self.state.p, &mut self.state.q);
            }
            reservoir_step = true;
            let drift = (self.state.p - self.gen_p).abs() + (self.state.q - self.gen_q).abs();
            if drift > self.cfg.adapt_drift_eps {
                engine.recalibrate(&ReservoirUpdate {
                    p: self.state.p,
                    q: self.state.q,
                    n_v: self.cfg.n_v,
                    t_max: self.obs_t_max,
                    u_max: self.obs_u_max,
                })?;
                let updates = self.reseed_online(engine, true)?;
                return Ok(FeedOutcome::Adapted {
                    generation: self.generation,
                    p: self.gen_p,
                    q: self.gen_q,
                    updates,
                    reservoir_step: true,
                });
            }
        }

        if let Some(refolded) = datapath_refold {
            return Ok(FeedOutcome::Adapted {
                generation: self.generation,
                p: self.gen_p,
                q: self.gen_q,
                updates: refolded,
                reservoir_step,
            });
        }
        if let Some(threshold) = self.cfg.fallback_error_rate {
            let cap = self.err_ring.len();
            if cap > 0 && self.err_len == cap && self.err_count as f32 > threshold * cap as f32 {
                self.reset_err();
                drop(span);
                return self.train(engine);
            }
        }
        Ok(FeedOutcome::Observed {
            updates: stats.updates,
            window: stats.window_len,
            reservoir_step,
        })
    }

    /// Roll the serving state onto a new reservoir generation:
    /// re-featurize the bounded ring buffer through the serving
    /// reservoir, reseed a fresh online ridge factor from those features
    /// (same β/λ/window as the old one), and refresh the served `W̃`.
    /// Returns the number of samples re-folded.
    ///
    /// `advance_params` distinguishes the two roll triggers: a
    /// drift-threshold roll (`true`) pins `(gen_p, gen_q)` to the
    /// freshly **recalibrated** candidate; a datapath-change roll
    /// (`false`) keeps the already-validated serving params and only
    /// regenerates the features under the engine's new datapath — the
    /// unvalidated candidate is never served, and its accumulated drift
    /// survives to trigger a proper recalibrated roll later.
    ///
    /// Factor and features are regenerated together under one generation
    /// bump, so no r̃ from generation G ever meets a factor from G' ≠ G.
    fn reseed_online(&mut self, engine: &dyn Engine, advance_params: bool) -> Result<u64> {
        if advance_params {
            self.gen_p = self.state.p;
            self.gen_q = self.state.q;
        }
        self.generation += 1;
        self.engine_generation = engine.generation();
        let (ocfg, s, ny) = match self.online.as_ref() {
            Some(o) => (o.config(), o.s(), o.ny()),
            None => anyhow::bail!("reseed requires the streaming path"),
        };
        let mut fresh = OnlineRidge::new(s, ny, ocfg);
        // window mode refolds the tail `window` samples; λ mode replays
        // the whole ring in arrival order so the geometric down-weighting
        // matches what the evicted factor carried
        let start = ocfg
            .window
            .map_or(0, |w| self.buffer.len().saturating_sub(w));
        let mut folded = 0u64;
        for i in start..self.buffer.len() {
            engine.features_into(
                &self.buffer[i],
                &self.mask,
                self.gen_p,
                self.gen_q,
                &mut self.feat_scratch,
            )?;
            fresh.fold(&self.feat_scratch, self.buffer[i].label);
            folded += 1;
        }
        fresh.solve_now();
        if let Some(sol) = self.solution.as_mut() {
            sol.w_tilde.copy_from_slice(fresh.w_tilde());
        }
        self.online = Some(fresh);
        self.reset_err();
        Ok(folded)
    }

    /// Force training with whatever is buffered.
    pub fn finalize(&mut self, engine: &dyn Engine) -> Result<FeedOutcome> {
        if self.buffer.is_empty() {
            return Ok(FeedOutcome::Rejected("no samples buffered".into()));
        }
        self.mutations += 1;
        self.train(engine)
    }

    /// The full §4.1 pipeline over the buffer. On an engine error the
    /// session's phase is restored to what it was at entry — without
    /// this, a transient fault mid-train would strand the session in
    /// `BpOptimize`, where no feed can ever trigger training again (the
    /// old solution/factor are untouched until the success path, so a
    /// Serve-phase session keeps serving its previous generation).
    fn train(&mut self, engine: &dyn Engine) -> Result<FeedOutcome> {
        let _span = trace::span(Stage::OnlineRidge);
        let entry_phase = self.phase;
        let out = self.train_inner(engine);
        match &out {
            // a completed batch train rebuilt every derived structure
            // from the raw buffer — whatever fault flagged the session
            // is healed by construction
            Ok(_) => self.degraded = false,
            Err(_) => self.phase = entry_phase,
        }
        out
    }

    fn train_inner(&mut self, engine: &dyn Engine) -> Result<FeedOutcome> {
        let sw = crate::util::timer::Stopwatch::start();
        self.phase = Phase::BpOptimize;
        let cfg = self.cfg.train.clone();
        self.state = TrainState::init(self.cfg.n_c, cfg.nx, cfg.p_init, cfg.q_init);

        let mut lr_res = cfg.lr_init;
        let mut lr_out = cfg.lr_init;
        let mut order: Vec<usize> = (0..self.buffer.len()).collect();
        self.epoch_losses.clear();
        // plateau stopping mirrors StreamingBpTrainer::end_epoch, so the
        // engine-driven batch path stops where the native trainer would
        let mut best_loss = f32::INFINITY;
        let mut since_best = 0usize;
        for epoch in 0..cfg.epochs {
            if cfg.res_decay_epochs.contains(&epoch) {
                lr_res *= 0.1;
            }
            if cfg.out_decay_epochs.contains(&epoch) {
                lr_out *= 0.1;
            }
            self.rng.shuffle(&mut order);
            let mut loss_sum = 0.0f64;
            for &i in &order {
                let s = &self.buffer[i];
                let loss = engine.train_step(s, &self.mask, &mut self.state, lr_res, lr_out)?;
                loss_sum += f64::from(loss);
                if cfg.project_to_search_range {
                    crate::dfr::grid::project_to_search_range(&mut self.state.p, &mut self.state.q);
                }
            }
            let mean = (loss_sum / self.buffer.len() as f64) as f32;
            self.epoch_losses.push(mean);
            if let Some(patience) = cfg.plateau_patience {
                if mean < best_loss - cfg.plateau_min_delta {
                    best_loss = mean;
                    since_best = 0;
                } else {
                    since_best += 1;
                    if since_best >= patience {
                        break;
                    }
                }
            }
        }

        // the batch train establishes new serving parameters too — give
        // the engine the same budget re-validation a drift roll gets
        // (a quantized engine may fall back to f32, or recover from an
        // earlier fallback) BEFORE extracting the ridge features, so the
        // layer is fitted to what the recalibrated datapath will serve
        for s in &self.buffer {
            self.obs_t_max = self.obs_t_max.max(s.t);
            for &u in &s.u {
                self.obs_u_max = self.obs_u_max.max(u.abs());
            }
        }
        engine.recalibrate(&ReservoirUpdate {
            p: self.state.p,
            q: self.state.q,
            n_v: self.cfg.n_v,
            t_max: self.obs_t_max,
            u_max: self.obs_u_max,
        })?;

        self.phase = Phase::RidgeTrain;
        let feats: Result<Vec<(Vec<f32>, usize)>> = self
            .buffer
            .iter()
            .map(|s| {
                engine
                    .features(s, &self.mask, self.state.p, self.state.q)
                    .map(|f| (f, s.label))
            })
            .collect();
        let feats = feats?;
        let sol = ridge_phase_from_features(&feats, self.cfg.n_c, &cfg);
        let beta = sol.beta;
        // (re)seed the streaming accumulator at the selected β; every
        // batch train resets the online state and the fallback ring
        self.online = online_ridge_from_features(&feats, self.cfg.n_c, &cfg, beta);
        self.reset_err();
        self.solution = Some(sol);
        // the batch train founds a new reservoir generation: features,
        // factor and served W̃ all belong to the state it converged at
        self.gen_p = self.state.p;
        self.gen_q = self.state.q;
        self.generation += 1;
        self.engine_generation = engine.generation();
        self.phase = Phase::Serve;
        self.new_since_train = 0;
        Ok(FeedOutcome::Trained {
            p: self.state.p,
            q: self.state.q,
            beta,
            train_seconds: sw.elapsed_secs(),
        })
    }

    /// Bring the served model onto the engine's current **datapath**
    /// generation before answering inference — the infer-side mirror of
    /// the check in `observe_online`, so a session receiving only
    /// `Infer` traffic cannot keep serving a W̃ solved under the
    /// pre-flip datapath against post-flip features. No-op unless the
    /// engine's datapath moved and the streaming factor exists to
    /// reseed from (the batch-only path re-aligns at its next retrain).
    /// Returns the number of samples re-folded when a reseed ran.
    pub fn sync_generation(&mut self, engine: &dyn Engine) -> Result<Option<u64>> {
        if self.phase == Phase::Serve
            && self.online.is_some()
            && engine.generation() != self.engine_generation
        {
            let _span = trace::span(Stage::OnlineRidge);
            return Ok(Some(self.reseed_online(engine, false)?));
        }
        Ok(None)
    }

    /// Inference; only valid in Serve. Runs against the **served
    /// generation's** reservoir parameters — coherent with the factor
    /// and W̃ even while the adaptation candidate drifts ahead. The
    /// server calls [`sync_generation`](Self::sync_generation) first so
    /// the served layer tracks shared-datapath changes.
    pub fn infer(
        &self,
        engine: &dyn Engine,
        sample: &Sample,
    ) -> Result<(usize, Vec<f32>), InferError> {
        if self.phase != Phase::Serve {
            return Err(InferError::NotServing {
                session: self.id,
                phase: self.phase,
            });
        }
        let Some(sol) = self.solution.as_ref() else {
            return Err(InferError::NotServing {
                session: self.id,
                phase: self.phase,
            });
        };
        let _span = trace::span(Stage::ScoreFold);
        let scores = engine
            .infer(sample, &self.mask, self.gen_p, self.gen_q, &sol.w_tilde)
            .map_err(InferError::Engine)?;
        let class = crate::linalg::ridge::argmax(&scores);
        Ok((class, scores))
    }

    /// Inference from a batch-extracted r̃ — the scoring tail of
    /// [`infer`](Self::infer) without the forward pass. Only valid when
    /// the engine's [`Engine::scores_from_features_exact`] contract
    /// holds (the server's planner checks it; batched `Infer` through a
    /// live quantized datapath keeps the per-call path instead, because
    /// its integer MAC is not a float dot over r̃). Same preconditions
    /// on feature freshness as
    /// [`feed_labelled_with_features`](Self::feed_labelled_with_features).
    pub fn infer_with_features(
        &self,
        engine: &dyn Engine,
        features: &[f32],
    ) -> Result<(usize, Vec<f32>), InferError> {
        if self.phase != Phase::Serve {
            return Err(InferError::NotServing {
                session: self.id,
                phase: self.phase,
            });
        }
        debug_assert!(
            engine.scores_from_features_exact(),
            "batched scoring requires an exact-score engine"
        );
        let Some(sol) = self.solution.as_ref() else {
            return Err(InferError::NotServing {
                session: self.id,
                phase: self.phase,
            });
        };
        let _span = trace::span(Stage::ScoreFold);
        let mut scores = Vec::new();
        // dot through the engine's own kernel table so the reduction
        // order matches its `infer_into` exactly (the bitwise
        // `scores_from_features_exact` contract holds per table)
        scores_from_r_tilde_with(&sol.w_tilde, features, &mut scores, &engine.kernels());
        let class = crate::linalg::ridge::argmax(&scores);
        Ok((class, scores))
    }

    /// Copy out the session's complete mutable state for durable
    /// checkpointing. [`restore`](Self::restore) on the result (with the
    /// same `SessionConfig`) yields a session whose every subsequent
    /// feed/infer response is **bitwise equal** to continuing on the
    /// original — the ring buffer, factor + Gram shadow, served W̃,
    /// candidate SGD state, PRNG position, generation counters and
    /// fallback ring all round-trip exactly.
    ///
    /// Two durability layers ride on this guarantee: periodic crash
    /// checkpoints ([`checkpoint`](super::checkpoint)) and session
    /// hibernation ([`hibernate`](super::hibernate)), which parks cold
    /// sessions off-heap and rehydrates them on the next touch with no
    /// observable response difference.
    pub fn snapshot(&self) -> SessionSnapshot {
        let (rng_state, rng_inc) = self.rng.state_parts();
        SessionSnapshot {
            id: self.id,
            phase: self.phase,
            mask_nx: self.mask.nx,
            mask_v: self.mask.v,
            mask_m: self.mask.m.clone(),
            buffer: self.buffer.iter().cloned().collect(),
            new_since_train: self.new_since_train,
            state_p: self.state.p,
            state_q: self.state.q,
            state_w: self.state.w.clone(),
            state_b: self.state.b.clone(),
            solution: self.solution.clone(),
            online: self.online.as_ref().map(|o| o.export_state()),
            err_ring: self.err_ring.clone(),
            err_head: self.err_head,
            err_len: self.err_len,
            err_count: self.err_count,
            rng_state,
            rng_inc,
            epoch_losses: self.epoch_losses.clone(),
            generation: self.generation,
            engine_generation: self.engine_generation,
            gen_p: self.gen_p,
            gen_q: self.gen_q,
            obs_t_max: self.obs_t_max,
            obs_u_max: self.obs_u_max,
            degraded: self.degraded,
            quarantines: self.quarantines,
            mutations: self.mutations,
        }
    }

    /// Rebuild a session from a [`snapshot`](Self::snapshot) under the
    /// server's current `SessionConfig`. Every structural invariant is
    /// re-validated as a typed error — the snapshot may come from a
    /// corrupted checkpoint or a server started with different knobs.
    pub fn restore(snap: SessionSnapshot, mut cfg: SessionConfig) -> Result<Session, String> {
        // mirror Session::new's ring growth so restore agrees with a
        // freshly constructed session under the same config
        if cfg.adapt_reservoir {
            if let Some(w) = cfg.train.window {
                cfg.buffer_cap = cfg.buffer_cap.max(w);
            }
        }
        if snap.mask_nx != cfg.train.nx || snap.mask_v != cfg.n_v {
            return Err(format!(
                "mask shape {}x{} does not match config {}x{}",
                snap.mask_nx, snap.mask_v, cfg.train.nx, cfg.n_v
            ));
        }
        if snap.mask_m.len() != snap.mask_nx * snap.mask_v {
            return Err(format!(
                "mask length {} != {}·{}",
                snap.mask_m.len(),
                snap.mask_nx,
                snap.mask_v
            ));
        }
        let nx = cfg.train.nx;
        if snap.state_w.len() != cfg.n_c * nx * (nx + 1) || snap.state_b.len() != cfg.n_c {
            return Err(format!(
                "SGD state shape w={} b={} does not match n_c={} nx={nx}",
                snap.state_w.len(),
                snap.state_b.len(),
                cfg.n_c
            ));
        }
        if snap.buffer.len() > cfg.buffer_cap {
            return Err(format!(
                "buffered {} samples exceeds cap {}",
                snap.buffer.len(),
                cfg.buffer_cap
            ));
        }
        for s in &snap.buffer {
            if s.label >= cfg.n_c || s.v() != cfg.n_v || !s.u.iter().all(|u| u.is_finite()) {
                return Err("invalid sample in buffer".into());
            }
        }
        if let Some(sol) = &snap.solution {
            if sol.w_tilde.len() != sol.s * sol.ny || sol.ny != cfg.n_c {
                return Err(format!(
                    "solution shape {}≠{}·{} (n_c {})",
                    sol.w_tilde.len(),
                    sol.s,
                    sol.ny,
                    cfg.n_c
                ));
            }
        }
        if snap.phase == Phase::Serve && snap.solution.is_none() {
            return Err("Serve phase without a solution".into());
        }
        let cap = snap.err_ring.len();
        if snap.err_len > cap || (cap > 0 && snap.err_head >= cap) || snap.err_count > snap.err_len
        {
            return Err(format!(
                "error-ring cursor out of range: head {} len {} count {} cap {cap}",
                snap.err_head, snap.err_len, snap.err_count
            ));
        }
        let online = match snap.online {
            Some(st) => {
                if st.ny != cfg.n_c {
                    return Err(format!("online factor ny {} != n_c {}", st.ny, cfg.n_c));
                }
                Some(OnlineRidge::from_state(st).map_err(|e| format!("online factor: {e}"))?)
            }
            None => None,
        };
        Ok(Session {
            id: snap.id,
            cfg,
            phase: snap.phase,
            mask: Mask {
                nx: snap.mask_nx,
                v: snap.mask_v,
                m: snap.mask_m,
            },
            buffer: snap.buffer.into(),
            new_since_train: snap.new_since_train,
            state: TrainState {
                p: snap.state_p,
                q: snap.state_q,
                w: snap.state_w,
                b: snap.state_b,
            },
            solution: snap.solution,
            online,
            feat_scratch: Vec::new(),
            err_ring: snap.err_ring,
            err_head: snap.err_head,
            err_len: snap.err_len,
            err_count: snap.err_count,
            rng: Pcg32::from_state_parts(snap.rng_state, snap.rng_inc),
            epoch_losses: snap.epoch_losses,
            generation: snap.generation,
            engine_generation: snap.engine_generation,
            gen_p: snap.gen_p,
            gen_q: snap.gen_q,
            obs_t_max: snap.obs_t_max,
            obs_u_max: snap.obs_u_max,
            degraded: snap.degraded,
            quarantines: snap.quarantines,
            mutations: snap.mutations,
        })
    }
}

/// Plain-data copy of a [`Session`]'s complete mutable state — the
/// serialization bridge between the live session and the checkpoint
/// codec (`coordinator/checkpoint.rs`). Everything that changes after
/// construction is here; the immutable `SessionConfig` is NOT (the
/// server re-supplies its current config on restore, which
/// [`Session::restore`] validates the snapshot against).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSnapshot {
    pub id: u64,
    pub phase: Phase,
    pub mask_nx: usize,
    pub mask_v: usize,
    pub mask_m: Vec<f32>,
    /// labelled-sample ring, oldest first
    pub buffer: Vec<Sample>,
    pub new_since_train: usize,
    /// candidate SGD state (truncated-BPTT optimizer position)
    pub state_p: f32,
    pub state_q: f32,
    pub state_w: Vec<f32>,
    pub state_b: Vec<f32>,
    /// served output layer
    pub solution: Option<RidgeSolution>,
    /// streaming accumulator (factor + Gram shadow + sample ring)
    pub online: Option<OnlineRidgeState>,
    /// rolling prequential-error ring
    pub err_ring: Vec<bool>,
    pub err_head: usize,
    pub err_len: usize,
    pub err_count: usize,
    /// PRNG position (epoch-shuffle stream continues exactly)
    pub rng_state: u64,
    pub rng_inc: u64,
    pub epoch_losses: Vec<f32>,
    pub generation: u64,
    pub engine_generation: u64,
    /// serving (p, q) of the current generation
    pub gen_p: f32,
    pub gen_q: f32,
    /// workload envelope for recalibration
    pub obs_t_max: usize,
    pub obs_u_max: f32,
    pub degraded: bool,
    pub quarantines: u64,
    /// freshness stamp: mutating requests applied over the session's
    /// lifetime; the restore path keeps the highest per id
    pub mutations: u64,
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::data::profiles::Profile;
    use crate::data::synth;

    fn setup() -> (NativeEngine, Session, crate::data::dataset::Dataset) {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 30,
            test: 10,
            t_min: 10,
            t_max: 14,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.2,
                ar: 0.3,
            },
            9,
        );
        let mut cfg = SessionConfig::new(2, 2, 30);
        cfg.train.nx = 8;
        cfg.train.epochs = 4;
        cfg.train.res_decay_epochs = vec![2];
        cfg.train.out_decay_epochs = vec![2];
        let sess = Session::new(1, cfg, 0xABC);
        (NativeEngine::new(8, 2), sess, ds)
    }

    #[test]
    fn lifecycle_collect_to_serve() {
        let (eng, mut sess, ds) = setup();
        assert_eq!(sess.phase, Phase::Collect);
        let n = ds.train.len();
        for (i, s) in ds.train.iter().enumerate() {
            let out = sess.feed_labelled(&eng, s.clone()).unwrap();
            if i + 1 < n {
                assert_eq!(out, FeedOutcome::Buffered(i + 1));
            } else {
                assert!(matches!(out, FeedOutcome::Trained { .. }), "{out:?}");
            }
        }
        assert_eq!(sess.phase, Phase::Serve);
        // inference works and is decent on this easy problem
        let mut ok = 0;
        for s in &ds.test {
            let (class, scores) = sess.infer(&eng, s).unwrap();
            assert_eq!(scores.len(), 2);
            if class == s.label {
                ok += 1;
            }
        }
        assert!(ok >= 7, "{ok}/10");
    }

    #[test]
    fn infer_rejected_before_training() {
        let (eng, sess, ds) = setup();
        let e = sess.infer(&eng, &ds.test[0]).unwrap_err();
        assert!(matches!(e, InferError::NotServing { .. }), "{e}");
        assert!(e.to_string().contains("not serving"), "{e}");
    }

    #[test]
    fn bad_label_rejected() {
        let (eng, mut sess, ds) = setup();
        let mut s = ds.train[0].clone();
        s.label = 99;
        let out = sess.feed_labelled(&eng, s).unwrap();
        assert!(matches!(out, FeedOutcome::Rejected(_)));
    }

    #[test]
    fn buffer_cap_backpressure() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.collect_target = usize::MAX; // never train
        sess.cfg.buffer_cap = 5;
        for i in 0..7 {
            let out = sess
                .feed_labelled(&eng, ds.train[i % ds.train.len()].clone())
                .unwrap();
            if i < 5 {
                assert!(matches!(out, FeedOutcome::Buffered(_)));
            } else {
                assert!(matches!(out, FeedOutcome::Rejected(_)));
            }
        }
    }

    #[test]
    fn finalize_trains_early() {
        let (eng, mut sess, ds) = setup();
        for s in ds.train.iter().take(8) {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        let out = sess.finalize(&eng).unwrap();
        assert!(matches!(out, FeedOutcome::Trained { .. }));
        assert_eq!(sess.phase, Phase::Serve);
    }

    #[test]
    fn retrain_on_drift() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.retrain_after = Some(4);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        // 4 more labelled samples trigger a retrain
        let mut outcomes = Vec::new();
        for s in ds.train.iter().take(4) {
            outcomes.push(sess.feed_labelled(&eng, s.clone()).unwrap());
        }
        assert!(matches!(outcomes.last().unwrap(), FeedOutcome::Trained { .. }));
    }

    #[test]
    fn streaming_serve_answers_observed_and_updates_solution() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.window = Some(16);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        assert!(sess.online().is_some(), "streaming accumulator seeded");
        let seeded_updates = sess.online().unwrap().updates();
        let w_before = sess.solution().unwrap().w_tilde.clone();
        let mut saw_change = false;
        for (i, s) in ds.train.iter().take(6).enumerate() {
            match sess.feed_labelled(&eng, s.clone()).unwrap() {
                FeedOutcome::Observed {
                    updates,
                    window,
                    reservoir_step,
                } => {
                    assert_eq!(updates, seeded_updates + i as u64 + 1);
                    assert!(window <= 16);
                    assert!(!reservoir_step, "adaptation is off by default");
                }
                other => panic!("expected Observed, got {other:?}"),
            }
            assert_eq!(sess.phase, Phase::Serve);
            if sess.solution().unwrap().w_tilde != w_before {
                saw_change = true;
            }
        }
        assert!(saw_change, "served W̃ never refreshed");
        // adaptation off → the candidate never drifts from the serving
        // generation and the generation stays at the batch train's
        assert_eq!(sess.params(), sess.serving_params());
        assert_eq!(sess.generation(), 1);
        // inference still works against the refreshed layer
        assert!(sess.infer(&eng, &ds.test[0]).is_ok());
    }

    #[test]
    fn streaming_fallback_retrains_on_sustained_errors() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.forgetting = Some(0.98);
        sess.cfg.fallback_error_rate = Some(0.6);
        sess.cfg.fallback_window = 6;
        // the ring was sized at construction; rebuild the session with
        // the final config (Session::new reads fallback_window)
        let cfg = sess.cfg.clone();
        let mut sess = Session::new(1, cfg, 0xABC);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        // feed deliberately mislabelled samples: the prequential error
        // climbs above the threshold and forces a batch retrain
        let mut fell_back = false;
        for i in 0..24 {
            let mut s = ds.train[i % ds.train.len()].clone();
            s.label = 1 - s.label; // systematic label flip = drift
            if let FeedOutcome::Trained { .. } = sess.feed_labelled(&eng, s).unwrap() {
                fell_back = true;
                break;
            }
        }
        assert!(fell_back, "sustained errors never triggered the batch fallback");
        assert_eq!(sess.phase, Phase::Serve);
        assert!(sess.online().is_some(), "fallback retrain reseeds the accumulator");
    }

    #[test]
    fn adaptation_steps_move_candidate_without_touching_serving_generation() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.window = Some(16);
        sess.cfg.adapt_reservoir = true;
        sess.cfg.adapt_lr = 0.05;
        sess.cfg.adapt_drift_eps = 1e9; // never roll the generation
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        assert_eq!(sess.generation(), 1);
        let served = sess.serving_params();
        let mut stepped = 0;
        for s in ds.train.iter().take(8) {
            match sess.feed_labelled(&eng, s.clone()).unwrap() {
                FeedOutcome::Observed { reservoir_step, .. } => {
                    assert!(reservoir_step, "adaptation must drive BP steps");
                    stepped += 1;
                }
                other => panic!("expected Observed, got {other:?}"),
            }
        }
        assert_eq!(stepped, 8);
        // the candidate moved, the served generation did not
        assert_ne!(sess.params(), served, "candidate (p, q) never moved");
        assert_eq!(sess.serving_params(), served);
        assert_eq!(sess.generation(), 1);
    }

    #[test]
    fn drift_threshold_rolls_generation_and_reseeds() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.window = Some(16);
        sess.cfg.adapt_reservoir = true;
        sess.cfg.adapt_lr = 0.05;
        sess.cfg.adapt_drift_eps = 1e-6; // any movement crosses
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.generation(), 1);
        let mut last_generation = sess.generation();
        let mut adapted = 0;
        for s in ds.train.iter().take(10) {
            match sess.feed_labelled(&eng, s.clone()).unwrap() {
                FeedOutcome::Adapted {
                    generation,
                    p,
                    q,
                    updates,
                    reservoir_step,
                } => {
                    adapted += 1;
                    assert!(reservoir_step, "drift rolls ride a BP step");
                    assert!(generation > last_generation, "generation must be monotonic");
                    last_generation = generation;
                    // the reseed pins serving to the candidate
                    assert_eq!((p, q), sess.serving_params());
                    assert_eq!((p, q), sess.params());
                    // window mode refolds at most `window` ring samples
                    assert!(updates > 0 && updates <= 16, "{updates}");
                }
                FeedOutcome::Observed { reservoir_step, .. } => assert!(reservoir_step),
                other => panic!("unexpected {other:?}"),
            }
            assert_eq!(sess.phase, Phase::Serve, "adaptation never leaves Serve");
        }
        assert!(adapted > 0, "drift threshold of 1e-6 never tripped");
        // the served model stays coherent: inference still works
        assert!(sess.infer(&eng, &ds.test[0]).is_ok());
    }

    #[test]
    fn snapshot_restore_is_bitwise_equivalent() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.window = Some(16);
        sess.cfg.adapt_reservoir = true;
        sess.cfg.adapt_lr = 0.05;
        sess.cfg.adapt_drift_eps = 0.5;
        sess.cfg.fallback_error_rate = Some(0.9);
        let cfg = sess.cfg.clone();
        let mut sess = Session::new(1, cfg.clone(), 0xABC);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        for s in ds.train.iter().take(5) {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        let mut twin = Session::restore(sess.snapshot(), cfg).unwrap();
        assert_eq!(twin.mutations(), sess.mutations());
        // both continue on identical input; every outcome and every
        // score vector must match bitwise (train_seconds is wall clock —
        // the only non-deterministic field, zeroed before comparing)
        fn norm(o: FeedOutcome) -> FeedOutcome {
            match o {
                FeedOutcome::Trained { p, q, beta, .. } => FeedOutcome::Trained {
                    p,
                    q,
                    beta,
                    train_seconds: 0.0,
                },
                other => other,
            }
        }
        for s in &ds.train {
            let a = sess.feed_labelled(&eng, s.clone()).unwrap();
            let b = twin.feed_labelled(&eng, s.clone()).unwrap();
            assert_eq!(norm(a), norm(b));
        }
        for s in &ds.test {
            let (ca, sa) = sess.infer(&eng, s).unwrap();
            let (cb, sb) = twin.infer(&eng, s).unwrap();
            assert_eq!(ca, cb);
            assert_eq!(sa, sb);
        }
    }

    #[test]
    fn restore_rejects_mismatched_config() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.window = Some(16);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        let good = sess.cfg.clone();
        let snap = sess.snapshot();
        let mut bad = good.clone();
        bad.train.nx = 12; // mask no longer matches
        assert!(Session::restore(snap.clone(), bad).is_err());
        let mut bad = good.clone();
        bad.n_c = 5; // SGD state + online factor shaped for 2 classes
        assert!(Session::restore(snap.clone(), bad).is_err());
        let mut corrupt = snap.clone();
        corrupt.solution = None; // Serve without a solution
        assert!(Session::restore(corrupt, good.clone()).is_err());
        assert!(Session::restore(snap, good).is_ok());
    }

    #[test]
    fn nonfinite_input_rejected_and_degraded_recovery_retrains() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.window = Some(16);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        // NaN input never reaches the engine
        let mut s = ds.train[0].clone();
        s.u[0] = f32::NAN;
        assert!(!sess.sample_valid(&s));
        match sess.feed_labelled(&eng, s).unwrap() {
            FeedOutcome::Rejected(msg) => assert!(msg.contains("non-finite"), "{msg}"),
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert_eq!(sess.quarantine_events(), 0);
        // degraded flag (set by the server on caught panics / NaN
        // scores) forces a recovery retrain on the next labelled feed
        sess.flag_degraded();
        assert!(sess.degraded());
        match sess.feed_labelled(&eng, ds.train[1].clone()).unwrap() {
            FeedOutcome::Trained { .. } => {}
            other => panic!("expected recovery Trained, got {other:?}"),
        }
        assert!(!sess.degraded());
        assert_eq!(sess.phase, Phase::Serve);
    }

    /// NativeEngine wrapper whose datapath generation can be flipped by
    /// the test — stands in for a shared quantized engine falling back
    /// to f32 (which is when `Engine::generation` really moves).
    struct FlippingEngine {
        inner: NativeEngine,
        gen: std::cell::Cell<u64>,
    }

    impl Engine for FlippingEngine {
        fn train_step(
            &self,
            s: &Sample,
            mask: &Mask,
            state: &mut crate::runtime::executor::TrainState,
            lr_res: f32,
            lr_out: f32,
        ) -> Result<f32> {
            self.inner.train_step(s, mask, state, lr_res, lr_out)
        }
        fn features(&self, s: &Sample, mask: &Mask, p: f32, q: f32) -> Result<Vec<f32>> {
            self.inner.features(s, mask, p, q)
        }
        fn features_into(
            &self,
            s: &Sample,
            mask: &Mask,
            p: f32,
            q: f32,
            out: &mut Vec<f32>,
        ) -> Result<()> {
            self.inner.features_into(s, mask, p, q, out)
        }
        fn infer(&self, s: &Sample, mask: &Mask, p: f32, q: f32, w: &[f32]) -> Result<Vec<f32>> {
            self.inner.infer(s, mask, p, q, w)
        }
        fn name(&self) -> &'static str {
            "flipping"
        }
        fn generation(&self) -> u64 {
            self.gen.get()
        }
    }

    #[test]
    fn engine_generation_change_forces_reseed_before_folding() {
        let (inner, mut sess, ds) = setup();
        let eng = FlippingEngine {
            inner,
            gen: std::cell::Cell::new(0),
        };
        sess.cfg.train.window = Some(16);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.generation(), 1);
        // the shared datapath changes under the session (e.g. another
        // session's recalibration flipped a quantized engine to f32)
        eng.gen.set(1);
        // the next labelled feed must reseed (datapath generation moved)
        // and answer Adapted at the session's own VALIDATED serving
        // parameters — a datapath roll never serves the candidate
        let before = sess.serving_params();
        match sess.feed_labelled(&eng, ds.train[0].clone()).unwrap() {
            FeedOutcome::Adapted {
                generation,
                p,
                q,
                updates,
                reservoir_step,
            } => {
                assert_eq!(generation, 2);
                assert_eq!((p, q), before, "datapath roll keeps the serving params");
                assert!(updates > 0);
                assert!(!reservoir_step, "adaptation is off in this session");
            }
            other => panic!("expected Adapted after datapath change, got {other:?}"),
        }
        // subsequent feeds are plain Observed again
        match sess.feed_labelled(&eng, ds.train[1].clone()).unwrap() {
            FeedOutcome::Observed { .. } => {}
            other => panic!("expected Observed, got {other:?}"),
        }

        // infer-only traffic tracks datapath changes too: the server
        // calls sync_generation before infer
        eng.gen.set(2);
        let refolded = sess.sync_generation(&eng).unwrap();
        assert!(refolded.is_some(), "datapath moved — must reseed");
        assert_eq!(sess.generation(), 3);
        assert_eq!(sess.serving_params(), before, "sync keeps serving params");
        assert!(
            sess.sync_generation(&eng).unwrap().is_none(),
            "aligned — second sync is a no-op"
        );
        assert!(sess.infer(&eng, &ds.test[0]).is_ok());
    }
}
