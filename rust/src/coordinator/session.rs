//! Per-deployment session: the paper's online protocol as a state
//! machine over an [`Engine`].
//!
//! ```text
//! Collect ─► BpOptimize ─► RidgeTrain ─► Serve
//! ```
//!
//! * **Collect** buffers labelled samples up to `collect_target` (bounded
//!   — edge memory budget).
//! * **BpOptimize** runs the §4.1 SGD protocol over the buffer via
//!   `Engine::train_step` (per-sample = true online SGD), with the LR
//!   decay schedule.
//! * **RidgeTrain** streams r̃ through the packed accumulator and solves
//!   with the in-place 1-D Cholesky per β, selecting by held-out loss.
//! * **Serve** answers inference requests. Labelled samples arriving in
//!   Serve adapt the model to drift by one of two paths:
//!   - **streaming** (when `TrainConfig::forgetting` or `::window` is
//!     set): each sample rank-1-updates the packed Cholesky factor and
//!     re-solves the output layer in place — O(s²) per sample, zero
//!     allocations, answered with `Observed` (the session never leaves
//!     Serve). A rolling-error fallback can still force the full batch
//!     pipeline when the online model stops tracking.
//!   - **batch** (otherwise): samples are buffered and `retrain_after`
//!     triggers the full §4.1 pipeline again.
//!
//! A `Session` is single-threaded by design: the server routes all
//! requests for one session id to the same shard thread, which owns the
//! session exclusively — no locking appears anywhere in this module.

use std::collections::VecDeque;

use anyhow::Result;

use super::engine::Engine;
use crate::data::dataset::Sample;
use crate::dfr::mask::Mask;
use crate::dfr::train::{online_ridge_from_features, ridge_phase_from_features, TrainConfig};
use crate::linalg::ridge::{OnlineRidge, RidgeSolution};
use crate::runtime::executor::TrainState;
use crate::util::prng::Pcg32;

/// Session lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Collect,
    BpOptimize,
    RidgeTrain,
    Serve,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Collect => "collect",
            Phase::BpOptimize => "bp_optimize",
            Phase::RidgeTrain => "ridge_train",
            Phase::Serve => "serve",
        }
    }
}

/// Session knobs.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// labelled samples to buffer before training starts
    pub collect_target: usize,
    /// hard cap on the buffer (backpressure boundary)
    pub buffer_cap: usize,
    /// the §4.1 protocol parameters
    pub train: TrainConfig,
    /// classes
    pub n_c: usize,
    /// input channels
    pub n_v: usize,
    /// retrain after this many new labelled samples arrive in Serve
    /// (None = never). Ignored while the streaming path is active
    /// (`train.forgetting` / `train.window`) — there every labelled
    /// sample already updates the model.
    pub retrain_after: Option<usize>,
    /// Streaming-path fallback: when the rolling error rate of the
    /// online model over the last [`fallback_window`](Self::fallback_window)
    /// labelled samples exceeds this, the session runs the full batch
    /// pipeline over its recent-sample buffer (`None` = never fall
    /// back). The error is *prequential* — each sample is scored by the
    /// model **before** it updates it, so the estimate is honest.
    pub fallback_error_rate: Option<f32>,
    /// size of the rolling error window (also the minimum number of
    /// streamed samples before the fallback can trigger)
    pub fallback_window: usize,
}

impl SessionConfig {
    pub fn new(n_v: usize, n_c: usize, collect_target: usize) -> Self {
        SessionConfig {
            collect_target,
            buffer_cap: collect_target * 2,
            train: TrainConfig::default(),
            n_c,
            n_v,
            retrain_after: None,
            fallback_error_rate: None,
            fallback_window: 32,
        }
    }
}

/// Result of feeding a sample.
#[derive(Debug, PartialEq)]
pub enum FeedOutcome {
    Buffered(usize),
    /// training ran and the session is now serving
    Trained {
        p: f32,
        q: f32,
        beta: f32,
        train_seconds: f64,
    },
    /// Serve-phase streaming update applied: the output layer was
    /// rank-1-updated and re-solved in place (no retrain, no phase
    /// change). `updates` is the accumulator's lifetime fold count,
    /// `window` its current occupancy.
    Observed { updates: u64, window: usize },
    Rejected(String),
}

/// One online deployment.
pub struct Session {
    pub id: u64,
    pub cfg: SessionConfig,
    pub phase: Phase,
    pub mask: Mask,
    /// labelled-sample buffer: append-only during Collect, bounded FIFO
    /// (O(1) pop_front) on the streaming Serve path
    buffer: VecDeque<Sample>,
    new_since_train: usize,
    state: TrainState,
    solution: Option<RidgeSolution>,
    /// Serve-phase streaming accumulator (present iff the config enables
    /// forgetting/window); reseeded by every batch train
    online: Option<OnlineRidge>,
    /// reusable r̃ buffer for the streaming path (zero-alloc steady state)
    feat_scratch: Vec<f32>,
    /// rolling prequential-error ring for the batch fallback
    err_ring: Vec<bool>,
    err_head: usize,
    err_len: usize,
    err_count: usize,
    rng: Pcg32,
    /// mean SGD loss per epoch of the last training run
    pub epoch_losses: Vec<f32>,
}

impl Session {
    pub fn new(id: u64, cfg: SessionConfig, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, id);
        let mask = Mask::random(cfg.train.nx, cfg.n_v, &mut rng);
        let state = TrainState::init(cfg.n_c, cfg.train.nx, cfg.train.p_init, cfg.train.q_init);
        let err_ring = vec![false; cfg.fallback_window];
        Session {
            id,
            cfg,
            phase: Phase::Collect,
            mask,
            buffer: VecDeque::new(),
            new_since_train: 0,
            state,
            solution: None,
            online: None,
            feat_scratch: Vec::new(),
            err_ring,
            err_head: 0,
            err_len: 0,
            err_count: 0,
            rng,
            epoch_losses: Vec::new(),
        }
    }

    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    pub fn solution(&self) -> Option<&RidgeSolution> {
        self.solution.as_ref()
    }

    /// The Serve-phase streaming accumulator, when active.
    pub fn online(&self) -> Option<&OnlineRidge> {
        self.online.as_ref()
    }

    pub fn params(&self) -> (f32, f32) {
        (self.state.p, self.state.q)
    }

    fn push_err(&mut self, is_err: bool) {
        let cap = self.err_ring.len();
        if cap == 0 {
            return;
        }
        if self.err_len == cap {
            self.err_count -= self.err_ring[self.err_head] as usize;
            self.err_ring[self.err_head] = is_err;
            self.err_head = (self.err_head + 1) % cap;
        } else {
            self.err_ring[(self.err_head + self.err_len) % cap] = is_err;
            self.err_len += 1;
        }
        self.err_count += is_err as usize;
    }

    fn reset_err(&mut self) {
        self.err_head = 0;
        self.err_len = 0;
        self.err_count = 0;
    }

    /// Feed one labelled sample. May trigger the full training pipeline.
    pub fn feed_labelled(&mut self, engine: &dyn Engine, sample: Sample) -> Result<FeedOutcome> {
        if sample.label >= self.cfg.n_c {
            return Ok(FeedOutcome::Rejected(format!(
                "label {} out of range ({})",
                sample.label, self.cfg.n_c
            )));
        }
        if sample.v() != self.cfg.n_v {
            return Ok(FeedOutcome::Rejected(format!(
                "channel count {} != {}",
                sample.v(),
                self.cfg.n_v
            )));
        }
        // streaming Serve path: O(s²) in-place adaptation, no buffering
        // backpressure (the recent-sample buffer is a bounded FIFO there)
        if self.phase == Phase::Serve && self.online.is_some() {
            return self.observe_online(engine, sample);
        }
        if self.buffer.len() >= self.cfg.buffer_cap {
            return Ok(FeedOutcome::Rejected("buffer full (backpressure)".into()));
        }
        self.buffer.push_back(sample);
        self.new_since_train += 1;

        let should_train = match self.phase {
            Phase::Collect => self.buffer.len() >= self.cfg.collect_target,
            Phase::Serve => self
                .cfg
                .retrain_after
                .is_some_and(|n| self.new_since_train >= n),
            _ => false,
        };
        if should_train {
            let t = self.train(engine)?;
            return Ok(t);
        }
        Ok(FeedOutcome::Buffered(self.buffer.len()))
    }

    /// The Serve-phase streaming update: extract r̃ into the session
    /// scratch, score the sample against the **pre-update** model
    /// (prequential error, feeds the fallback trigger), fold it into the
    /// online accumulator, and refresh the served `W̃_out` in place.
    /// Zero heap allocations in steady state (`tests/zero_alloc.rs`).
    fn observe_online(&mut self, engine: &dyn Engine, sample: Sample) -> Result<FeedOutcome> {
        engine.features_into(
            &sample,
            &self.mask,
            self.state.p,
            self.state.q,
            &mut self.feat_scratch,
        )?;
        let (stats, mispredicted) = {
            let online = self.online.as_mut().expect("streaming serve path");
            let mispredicted = online.predict_class(&self.feat_scratch) != sample.label;
            (online.observe(&self.feat_scratch, sample.label), mispredicted)
        };
        self.push_err(mispredicted);
        if let Some(sol) = self.solution.as_mut() {
            sol.w_tilde
                .copy_from_slice(self.online.as_ref().expect("just used").w_tilde());
        }
        // keep a bounded FIFO of recent labelled samples so the batch
        // fallback has something to retrain on
        if !self.buffer.is_empty() && self.buffer.len() >= self.cfg.buffer_cap {
            self.buffer.pop_front();
        }
        self.buffer.push_back(sample);
        self.new_since_train += 1;
        if let Some(threshold) = self.cfg.fallback_error_rate {
            let cap = self.err_ring.len();
            if cap > 0 && self.err_len == cap && self.err_count as f32 > threshold * cap as f32 {
                self.reset_err();
                return self.train(engine);
            }
        }
        Ok(FeedOutcome::Observed {
            updates: stats.updates,
            window: stats.window_len,
        })
    }

    /// Force training with whatever is buffered.
    pub fn finalize(&mut self, engine: &dyn Engine) -> Result<FeedOutcome> {
        if self.buffer.is_empty() {
            return Ok(FeedOutcome::Rejected("no samples buffered".into()));
        }
        self.train(engine)
    }

    /// The full §4.1 pipeline over the buffer.
    fn train(&mut self, engine: &dyn Engine) -> Result<FeedOutcome> {
        let sw = crate::util::timer::Stopwatch::start();
        self.phase = Phase::BpOptimize;
        let cfg = self.cfg.train.clone();
        self.state = TrainState::init(self.cfg.n_c, cfg.nx, cfg.p_init, cfg.q_init);

        let mut lr_res = cfg.lr_init;
        let mut lr_out = cfg.lr_init;
        let mut order: Vec<usize> = (0..self.buffer.len()).collect();
        self.epoch_losses.clear();
        for epoch in 0..cfg.epochs {
            if cfg.res_decay_epochs.contains(&epoch) {
                lr_res *= 0.1;
            }
            if cfg.out_decay_epochs.contains(&epoch) {
                lr_out *= 0.1;
            }
            self.rng.shuffle(&mut order);
            let mut loss_sum = 0.0f64;
            for &i in &order {
                let s = &self.buffer[i];
                let loss = engine.train_step(s, &self.mask, &mut self.state, lr_res, lr_out)?;
                loss_sum += f64::from(loss);
                if cfg.project_to_search_range {
                    let (plo, phi) = crate::dfr::grid::P_EXP_RANGE;
                    let (qlo, qhi) = crate::dfr::grid::Q_EXP_RANGE;
                    self.state.p = self.state.p.clamp(10f32.powf(plo), 10f32.powf(phi));
                    self.state.q = self.state.q.clamp(10f32.powf(qlo), 10f32.powf(qhi));
                }
            }
            self.epoch_losses
                .push((loss_sum / self.buffer.len() as f64) as f32);
        }

        self.phase = Phase::RidgeTrain;
        let feats: Result<Vec<(Vec<f32>, usize)>> = self
            .buffer
            .iter()
            .map(|s| {
                engine
                    .features(s, &self.mask, self.state.p, self.state.q)
                    .map(|f| (f, s.label))
            })
            .collect();
        let feats = feats?;
        let sol = ridge_phase_from_features(&feats, self.cfg.n_c, &cfg);
        let beta = sol.beta;
        // (re)seed the streaming accumulator at the selected β; every
        // batch train resets the online state and the fallback ring
        self.online = online_ridge_from_features(&feats, self.cfg.n_c, &cfg, beta);
        self.reset_err();
        self.solution = Some(sol);
        self.phase = Phase::Serve;
        self.new_since_train = 0;
        Ok(FeedOutcome::Trained {
            p: self.state.p,
            q: self.state.q,
            beta,
            train_seconds: sw.elapsed_secs(),
        })
    }

    /// Inference; only valid in Serve.
    pub fn infer(&self, engine: &dyn Engine, sample: &Sample) -> Result<Result<(usize, Vec<f32>), String>> {
        if self.phase != Phase::Serve {
            return Ok(Err(format!(
                "session {} not serving (phase {})",
                self.id,
                self.phase.name()
            )));
        }
        let sol = self.solution.as_ref().expect("serve implies solution");
        let scores = engine.infer(
            sample,
            &self.mask,
            self.state.p,
            self.state.q,
            &sol.w_tilde,
        )?;
        let class = crate::linalg::ridge::argmax(&scores);
        Ok(Ok((class, scores)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::data::profiles::Profile;
    use crate::data::synth;

    fn setup() -> (NativeEngine, Session, crate::data::dataset::Dataset) {
        let prof = Profile {
            name: "mini",
            n_v: 2,
            n_c: 2,
            train: 30,
            test: 10,
            t_min: 10,
            t_max: 14,
        };
        let ds = synth::generate_with(
            &prof,
            synth::SynthConfig {
                noise: 0.3,
                freq_sep: 0.2,
                ar: 0.3,
            },
            9,
        );
        let mut cfg = SessionConfig::new(2, 2, 30);
        cfg.train.nx = 8;
        cfg.train.epochs = 4;
        cfg.train.res_decay_epochs = vec![2];
        cfg.train.out_decay_epochs = vec![2];
        let sess = Session::new(1, cfg, 0xABC);
        (NativeEngine::new(8, 2), sess, ds)
    }

    #[test]
    fn lifecycle_collect_to_serve() {
        let (eng, mut sess, ds) = setup();
        assert_eq!(sess.phase, Phase::Collect);
        let n = ds.train.len();
        for (i, s) in ds.train.iter().enumerate() {
            let out = sess.feed_labelled(&eng, s.clone()).unwrap();
            if i + 1 < n {
                assert_eq!(out, FeedOutcome::Buffered(i + 1));
            } else {
                assert!(matches!(out, FeedOutcome::Trained { .. }), "{out:?}");
            }
        }
        assert_eq!(sess.phase, Phase::Serve);
        // inference works and is decent on this easy problem
        let mut ok = 0;
        for s in &ds.test {
            let (class, scores) = sess.infer(&eng, s).unwrap().unwrap();
            assert_eq!(scores.len(), 2);
            if class == s.label {
                ok += 1;
            }
        }
        assert!(ok >= 7, "{ok}/10");
    }

    #[test]
    fn infer_rejected_before_training() {
        let (eng, sess, ds) = setup();
        let r = sess.infer(&eng, &ds.test[0]).unwrap();
        assert!(r.is_err());
    }

    #[test]
    fn bad_label_rejected() {
        let (eng, mut sess, ds) = setup();
        let mut s = ds.train[0].clone();
        s.label = 99;
        let out = sess.feed_labelled(&eng, s).unwrap();
        assert!(matches!(out, FeedOutcome::Rejected(_)));
    }

    #[test]
    fn buffer_cap_backpressure() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.collect_target = usize::MAX; // never train
        sess.cfg.buffer_cap = 5;
        for i in 0..7 {
            let out = sess
                .feed_labelled(&eng, ds.train[i % ds.train.len()].clone())
                .unwrap();
            if i < 5 {
                assert!(matches!(out, FeedOutcome::Buffered(_)));
            } else {
                assert!(matches!(out, FeedOutcome::Rejected(_)));
            }
        }
    }

    #[test]
    fn finalize_trains_early() {
        let (eng, mut sess, ds) = setup();
        for s in ds.train.iter().take(8) {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        let out = sess.finalize(&eng).unwrap();
        assert!(matches!(out, FeedOutcome::Trained { .. }));
        assert_eq!(sess.phase, Phase::Serve);
    }

    #[test]
    fn retrain_on_drift() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.retrain_after = Some(4);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        // 4 more labelled samples trigger a retrain
        let mut outcomes = Vec::new();
        for s in ds.train.iter().take(4) {
            outcomes.push(sess.feed_labelled(&eng, s.clone()).unwrap());
        }
        assert!(matches!(outcomes.last().unwrap(), FeedOutcome::Trained { .. }));
    }

    #[test]
    fn streaming_serve_answers_observed_and_updates_solution() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.window = Some(16);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        assert!(sess.online().is_some(), "streaming accumulator seeded");
        let seeded_updates = sess.online().unwrap().updates();
        let w_before = sess.solution().unwrap().w_tilde.clone();
        let mut saw_change = false;
        for (i, s) in ds.train.iter().take(6).enumerate() {
            match sess.feed_labelled(&eng, s.clone()).unwrap() {
                FeedOutcome::Observed { updates, window } => {
                    assert_eq!(updates, seeded_updates + i as u64 + 1);
                    assert!(window <= 16);
                }
                other => panic!("expected Observed, got {other:?}"),
            }
            assert_eq!(sess.phase, Phase::Serve);
            if sess.solution().unwrap().w_tilde != w_before {
                saw_change = true;
            }
        }
        assert!(saw_change, "served W̃ never refreshed");
        // inference still works against the refreshed layer
        let r = sess.infer(&eng, &ds.test[0]).unwrap();
        assert!(r.is_ok());
    }

    #[test]
    fn streaming_fallback_retrains_on_sustained_errors() {
        let (eng, mut sess, ds) = setup();
        sess.cfg.train.forgetting = Some(0.98);
        sess.cfg.fallback_error_rate = Some(0.6);
        sess.cfg.fallback_window = 6;
        // the ring was sized at construction; rebuild the session with
        // the final config (Session::new reads fallback_window)
        let cfg = sess.cfg.clone();
        let mut sess = Session::new(1, cfg, 0xABC);
        for s in &ds.train {
            sess.feed_labelled(&eng, s.clone()).unwrap();
        }
        assert_eq!(sess.phase, Phase::Serve);
        // feed deliberately mislabelled samples: the prequential error
        // climbs above the threshold and forces a batch retrain
        let mut fell_back = false;
        for i in 0..24 {
            let mut s = ds.train[i % ds.train.len()].clone();
            s.label = 1 - s.label; // systematic label flip = drift
            if let FeedOutcome::Trained { .. } = sess.feed_labelled(&eng, s).unwrap() {
                fell_back = true;
                break;
            }
        }
        assert!(fell_back, "sustained errors never triggered the batch fallback");
        assert_eq!(sess.phase, Phase::Serve);
        assert!(sess.online().is_some(), "fallback retrain reseeds the accumulator");
    }
}
