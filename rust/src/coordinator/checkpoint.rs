//! Durable session checkpoint/restore (DESIGN.md §15).
//!
//! Every shard periodically serializes its sessions into one
//! stored-zip archive (`<dir>/shard-<i>.ckpt`, written through
//! [`crate::data::zipstore`]) with one entry per session. Each entry is
//! a self-describing binary record:
//!
//! ```text
//! "DFRC" · version u8 · payload (little-endian) · CRC-32 u32
//! ```
//!
//! The CRC covers `version + payload`, so a single flipped bit anywhere
//! in the record is caught even if the surrounding zip container still
//! parses. Writes are atomic (write `*.tmp`, then `rename`): a crash
//! mid-write leaves the previous complete checkpoint in place, never a
//! torn file. On restore, [`load_all`] reads every `*.ckpt` in the
//! directory, skips (and counts) anything corrupt, and dedupes by
//! session id — the snapshot with the highest
//! [`mutations`](SessionSnapshot::mutations) stamp wins, so a stale
//! archive left behind by a dead shard can never roll a session back
//! past a fresher one.
//!
//! The codec is **complete**: ring buffer, packed Cholesky factor +
//! Gram shadow, served W̃, candidate SGD state, PRNG position,
//! generation counters, serving (p, q), fallback ring and the degraded
//! flag all round-trip, so a restored session's subsequent responses
//! are bitwise equal to an uninterrupted run (`Session::restore`'s
//! contract; proven in `tests/fault_injection.rs`).

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use super::session::{Phase, Session, SessionSnapshot};
use crate::data::dataset::Sample;
use crate::data::zipstore::{crc32, read_archive, write_archive, Entry};
use crate::linalg::ridge::{OnlineRidgeConfig, OnlineRidgeState, RidgeSolution};

/// Checkpointing knobs carried by `ServerConfig`.
#[derive(Clone, Debug)]
pub struct CheckpointConfig {
    /// directory holding one `shard-<i>.ckpt` archive per shard
    pub dir: PathBuf,
    /// write a snapshot after this many state-mutating requests
    /// (labelled feeds / finalizes) per shard; a final snapshot is also
    /// written on clean shutdown
    pub every: u64,
}

impl CheckpointConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        CheckpointConfig {
            dir: dir.into(),
            every: 64,
        }
    }
}

/// Probe whether `dir` can actually take a checkpoint write: create it
/// if missing, then create-and-remove a probe file. A read-only mount or
/// a path squatted by a regular file both fail here, which is exactly
/// what `/readyz` wants to know *before* the next cadence write discovers
/// it the hard way. The probe name is fixed — concurrent probes race
/// benignly (worst case one removes the other's file; both saw a
/// successful create).
pub fn dir_writable(dir: &Path) -> bool {
    if fs::create_dir_all(dir).is_err() {
        return false;
    }
    let probe = dir.join(".readyz-probe");
    match fs::write(&probe, b"probe") {
        Ok(()) => {
            let _ = fs::remove_file(&probe);
            true
        }
        Err(_) => false,
    }
}

/// Why a checkpoint record failed to decode. Corruption is an expected
/// runtime condition (torn disk, bit rot, foreign file) — every variant
/// is a typed error; the decoder never panics on any input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// the record does not start with the `DFRC` magic
    BadMagic,
    /// the version byte is not one this decoder understands
    BadVersion(u8),
    /// the record ends before its structure says it should
    Truncated,
    /// the CRC-32 trailer does not match the record body
    CrcMismatch,
    /// structurally parseable but semantically impossible content
    Invalid(String),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::BadMagic => write!(f, "bad checkpoint magic"),
            CheckpointError::BadVersion(v) => write!(f, "unsupported checkpoint version {v}"),
            CheckpointError::Truncated => write!(f, "truncated checkpoint record"),
            CheckpointError::CrcMismatch => write!(f, "checkpoint CRC mismatch"),
            CheckpointError::Invalid(why) => write!(f, "invalid checkpoint: {why}"),
        }
    }
}

impl std::error::Error for CheckpointError {}

const MAGIC: &[u8; 4] = b"DFRC";
const VERSION: u8 = 1;
/// Sanity cap on every length prefix: no real session holds a vector
/// beyond this, so a corrupt length can never drive a huge allocation.
const MAX_LEN: usize = 1 << 24;

// ---------------------------------------------------------------------
// little-endian writer

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Self {
        Writer {
            buf: Vec::with_capacity(1024),
        }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.f32(x);
        }
    }
    fn usizes(&mut self, v: &[usize]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.usize(x);
        }
    }
    fn bools(&mut self, v: &[bool]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u8(x as u8);
        }
    }
}

// ---------------------------------------------------------------------
// little-endian reader — every read is bounds-checked and every length
// prefix sanity-capped; out-of-bounds is `Truncated`, never a panic

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        let end = self.pos.checked_add(n).ok_or(CheckpointError::Truncated)?;
        if end > self.buf.len() {
            return Err(CheckpointError::Truncated);
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, CheckpointError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn u64(&mut self) -> Result<u64, CheckpointError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }
    fn f32(&mut self) -> Result<f32, CheckpointError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn usize(&mut self) -> Result<usize, CheckpointError> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| CheckpointError::Invalid(format!("usize overflow: {v}")))
    }
    fn len(&mut self) -> Result<usize, CheckpointError> {
        let n = self.u32()? as usize;
        if n > MAX_LEN {
            return Err(CheckpointError::Invalid(format!(
                "length prefix {n} exceeds cap {MAX_LEN}"
            )));
        }
        Ok(n)
    }
    fn f32s(&mut self) -> Result<Vec<f32>, CheckpointError> {
        let n = self.len()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }
    fn usizes(&mut self) -> Result<Vec<usize>, CheckpointError> {
        let n = self.len()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.usize()?);
        }
        Ok(out)
    }
    fn bools(&mut self) -> Result<Vec<bool>, CheckpointError> {
        let n = self.len()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u8()? != 0);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// codec

/// Serialize one session snapshot into a self-contained, CRC-guarded
/// record (the payload of one zip entry).
pub fn encode_session(snap: &SessionSnapshot) -> Vec<u8> {
    let mut w = Writer::new();
    w.buf.extend_from_slice(MAGIC);
    w.u8(VERSION);
    let body_start = w.buf.len() - 1; // CRC covers version + payload

    w.u64(snap.id);
    w.u8(snap.phase.code());
    w.u32(snap.mask_nx as u32);
    w.u32(snap.mask_v as u32);
    w.f32s(&snap.mask_m);
    w.u32(snap.buffer.len() as u32);
    for s in &snap.buffer {
        w.u32(s.t as u32);
        w.u32(s.label as u32);
        w.f32s(&s.u);
    }
    w.usize(snap.new_since_train);
    w.f32(snap.state_p);
    w.f32(snap.state_q);
    w.f32s(&snap.state_w);
    w.f32s(&snap.state_b);
    match &snap.solution {
        None => w.u8(0),
        Some(sol) => {
            w.u8(1);
            w.u32(sol.s as u32);
            w.u32(sol.ny as u32);
            w.f32(sol.beta);
            w.usize(sol.memory_words);
            w.f32s(&sol.w_tilde);
        }
    }
    match &snap.online {
        None => w.u8(0),
        Some(o) => {
            w.u8(1);
            w.f32(o.cfg.beta);
            w.f32(o.cfg.lambda);
            match o.cfg.window {
                None => w.u8(0),
                Some(win) => {
                    w.u8(1);
                    w.u32(win as u32);
                }
            }
            w.u32(o.cfg.refactor_every as u32);
            w.u32(o.s as u32);
            w.u32(o.ny as u32);
            w.f32s(&o.chol);
            w.f32s(&o.b);
            w.f32s(&o.a);
            w.f32s(&o.w);
            w.f32s(&o.ring);
            w.usizes(&o.ring_labels);
            w.u32(o.ring_head as u32);
            w.u32(o.ring_len as u32);
            w.u64(o.updates);
            w.u32(o.since_refactor as u32);
            w.u64(o.refactors);
        }
    }
    w.bools(&snap.err_ring);
    w.u32(snap.err_head as u32);
    w.u32(snap.err_len as u32);
    w.u32(snap.err_count as u32);
    w.u64(snap.rng_state);
    w.u64(snap.rng_inc);
    w.f32s(&snap.epoch_losses);
    w.u64(snap.generation);
    w.u64(snap.engine_generation);
    w.f32(snap.gen_p);
    w.f32(snap.gen_q);
    w.usize(snap.obs_t_max);
    w.f32(snap.obs_u_max);
    w.u8(snap.degraded as u8);
    w.u64(snap.quarantines);
    w.u64(snap.mutations);

    let crc = crc32(&w.buf[body_start..]);
    let mut out = w.buf;
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode one record back into a snapshot. Any malformation — wrong
/// magic, unknown version, truncation anywhere, a flipped bit, an
/// impossible length — comes back as a typed [`CheckpointError`].
pub fn decode_session(data: &[u8]) -> Result<SessionSnapshot, CheckpointError> {
    if data.len() < MAGIC.len() + 1 + 4 {
        return Err(CheckpointError::Truncated);
    }
    if &data[..MAGIC.len()] != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let body = &data[MAGIC.len()..data.len() - 4];
    let trailer = &data[data.len() - 4..];
    let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    if crc32(body) != stored {
        return Err(CheckpointError::CrcMismatch);
    }
    let mut r = Reader::new(body);
    let version = r.u8()?;
    if version != VERSION {
        return Err(CheckpointError::BadVersion(version));
    }

    let id = r.u64()?;
    let phase_code = r.u8()?;
    let phase = Phase::from_code(phase_code)
        .ok_or_else(|| CheckpointError::Invalid(format!("phase code {phase_code}")))?;
    let mask_nx = r.u32()? as usize;
    let mask_v = r.u32()? as usize;
    let mask_m = r.f32s()?;
    let n_samples = r.len()?;
    let mut buffer = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let t = r.u32()? as usize;
        let label = r.u32()? as usize;
        let u = r.f32s()?;
        buffer.push(Sample { u, t, label });
    }
    let new_since_train = r.usize()?;
    let state_p = r.f32()?;
    let state_q = r.f32()?;
    let state_w = r.f32s()?;
    let state_b = r.f32s()?;
    let solution = match r.u8()? {
        0 => None,
        1 => {
            let s = r.u32()? as usize;
            let ny = r.u32()? as usize;
            let beta = r.f32()?;
            let memory_words = r.usize()?;
            let w_tilde = r.f32s()?;
            // checked_mul, not saturating_mul: dims absurd enough to
            // overflow must be rejected as corruption, not compared
            // against usize::MAX (which a saturating product would let a
            // usize::MAX-length claim "match" on narrower targets)
            let expect = s.checked_mul(ny).ok_or_else(|| {
                CheckpointError::Invalid(format!("solution dims overflow: {s}·{ny}"))
            })?;
            if w_tilde.len() != expect {
                return Err(CheckpointError::Invalid(format!(
                    "solution length {} != {s}·{ny}",
                    w_tilde.len()
                )));
            }
            Some(RidgeSolution {
                w_tilde,
                s,
                ny,
                beta,
                memory_words,
            })
        }
        tag => return Err(CheckpointError::Invalid(format!("solution tag {tag}"))),
    };
    let online = match r.u8()? {
        0 => None,
        1 => {
            let beta = r.f32()?;
            let lambda = r.f32()?;
            let window = match r.u8()? {
                0 => None,
                1 => Some(r.u32()? as usize),
                tag => return Err(CheckpointError::Invalid(format!("window tag {tag}"))),
            };
            let refactor_every = r.u32()? as usize;
            let s = r.u32()? as usize;
            let ny = r.u32()? as usize;
            Some(OnlineRidgeState {
                cfg: OnlineRidgeConfig {
                    beta,
                    lambda,
                    window,
                    refactor_every,
                },
                s,
                ny,
                chol: r.f32s()?,
                b: r.f32s()?,
                a: r.f32s()?,
                w: r.f32s()?,
                ring: r.f32s()?,
                ring_labels: r.usizes()?,
                ring_head: r.u32()? as usize,
                ring_len: r.u32()? as usize,
                updates: r.u64()?,
                since_refactor: r.u32()? as usize,
                refactors: r.u64()?,
            })
        }
        tag => return Err(CheckpointError::Invalid(format!("online tag {tag}"))),
    };
    let err_ring = r.bools()?;
    let err_head = r.u32()? as usize;
    let err_len = r.u32()? as usize;
    let err_count = r.u32()? as usize;
    let rng_state = r.u64()?;
    let rng_inc = r.u64()?;
    let epoch_losses = r.f32s()?;
    let generation = r.u64()?;
    let engine_generation = r.u64()?;
    let gen_p = r.f32()?;
    let gen_q = r.f32()?;
    let obs_t_max = r.usize()?;
    let obs_u_max = r.f32()?;
    let degraded = r.u8()? != 0;
    let quarantines = r.u64()?;
    let mutations = r.u64()?;
    if r.pos != r.buf.len() {
        return Err(CheckpointError::Invalid(format!(
            "{} trailing bytes after payload",
            r.buf.len() - r.pos
        )));
    }

    Ok(SessionSnapshot {
        id,
        phase,
        mask_nx,
        mask_v,
        mask_m,
        buffer,
        new_since_train,
        state_p,
        state_q,
        state_w,
        state_b,
        solution,
        online,
        err_ring,
        err_head,
        err_len,
        err_count,
        rng_state,
        rng_inc,
        epoch_losses,
        generation,
        engine_generation,
        gen_p,
        gen_q,
        obs_t_max,
        obs_u_max,
        degraded,
        quarantines,
        mutations,
    })
}

// ---------------------------------------------------------------------
// shard-side writer

/// Per-shard checkpoint writer: counts mutating requests and writes one
/// atomic archive per cadence tick (plus a final one on shutdown).
pub struct ShardCheckpointer {
    dir: PathBuf,
    every: u64,
    shard: usize,
    pending: u64,
}

impl ShardCheckpointer {
    pub fn new(cfg: &CheckpointConfig, shard: usize) -> Self {
        ShardCheckpointer {
            dir: cfg.dir.clone(),
            every: cfg.every.max(1),
            shard,
            pending: 0,
        }
    }

    fn path(&self) -> PathBuf {
        self.dir.join(format!("shard-{}.ckpt", self.shard))
    }

    /// Record one state-mutating request; `true` means the cadence is
    /// due and the caller should invoke [`write_now`](Self::write_now).
    pub fn note_mutation(&mut self) -> bool {
        self.pending += 1;
        self.pending >= self.every
    }

    /// Snapshot every session into the shard archive, atomically:
    /// the bytes land in `shard-<i>.ckpt.tmp` first and replace the
    /// previous checkpoint only via `rename`, so a crash mid-write can
    /// never leave a torn file behind.
    pub fn write_now<'a>(
        &mut self,
        sessions: impl Iterator<Item = &'a Session>,
    ) -> std::io::Result<()> {
        let entries: Vec<Entry> = sessions
            .map(|sess| Entry {
                name: format!("session-{}", sess.id),
                data: encode_session(&sess.snapshot()),
            })
            .collect();
        let bytes = write_archive(&entries)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e))?;
        fs::create_dir_all(&self.dir)?;
        let tmp = self.dir.join(format!("shard-{}.ckpt.tmp", self.shard));
        fs::write(&tmp, &bytes)?;
        fs::rename(&tmp, self.path())?;
        self.pending = 0;
        Ok(())
    }
}

/// Read every `*.ckpt` archive in `dir` and return the freshest
/// snapshot per session id (highest [`SessionSnapshot::mutations`]
/// wins) plus the number of corrupt records/archives skipped. A missing
/// or unreadable directory is simply an empty restore — cold start is
/// not an error.
pub fn load_all(dir: &Path) -> (Vec<SessionSnapshot>, u64) {
    let mut best: BTreeMap<u64, SessionSnapshot> = BTreeMap::new();
    let mut corrupt = 0u64;
    let Ok(rd) = fs::read_dir(dir) else {
        return (Vec::new(), 0);
    };
    for dirent in rd.flatten() {
        let path = dirent.path();
        if path.extension().and_then(|e| e.to_str()) != Some("ckpt") {
            continue;
        }
        let Ok(bytes) = fs::read(&path) else {
            corrupt += 1;
            continue;
        };
        let entries = match read_archive(&bytes) {
            Ok(entries) => entries,
            Err(_) => {
                corrupt += 1;
                continue;
            }
        };
        for entry in entries {
            match decode_session(&entry.data) {
                Ok(snap) => {
                    let keep = best
                        .get(&snap.id)
                        .map_or(true, |cur| snap.mutations > cur.mutations);
                    if keep {
                        best.insert(snap.id, snap);
                    }
                }
                Err(_) => corrupt += 1,
            }
        }
    }
    (best.into_values().collect(), corrupt)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    /// Random-but-valid snapshot generator spanning the codec's whole
    /// shape space: with/without solution, with/without online factor
    /// (window / λ / grow modes), empty and populated rings.
    fn random_snapshot(rng: &mut Pcg32, id: u64) -> SessionSnapshot {
        let nx = 2 + rng.below(6) as usize;
        let n_v = 1 + rng.below(3) as usize;
        let n_c = 2 + rng.below(3) as usize;
        let s = nx + 1;
        let n_buf = rng.below(5) as usize;
        let buffer: Vec<Sample> = (0..n_buf)
            .map(|_| {
                let t = 1 + rng.below(4) as usize;
                Sample {
                    u: (0..t * n_v).map(|_| rng.normal()).collect(),
                    t,
                    label: rng.below(n_c as u32) as usize,
                }
            })
            .collect();
        let mode = rng.below(3);
        let window = if mode == 0 { Some(1 + rng.below(4) as usize) } else { None };
        let lambda = if mode == 1 { 0.9 + 0.05 * rng.uniform() } else { 1.0 };
        let has_online = rng.below(4) != 0;
        let online = has_online.then(|| {
            let win = window.unwrap_or(0);
            let ring_len = if win > 0 { rng.below(win as u32 + 1) as usize } else { 0 };
            OnlineRidgeState {
                cfg: OnlineRidgeConfig {
                    beta: 0.1 + rng.uniform(),
                    lambda,
                    window,
                    refactor_every: rng.below(8) as usize,
                },
                s,
                ny: n_c,
                chol: (0..s * (s + 1) / 2).map(|_| rng.normal()).collect(),
                b: (0..s * (s + 1) / 2).map(|_| rng.normal()).collect(),
                a: (0..n_c * s).map(|_| rng.normal()).collect(),
                w: (0..n_c * s).map(|_| rng.normal()).collect(),
                ring: (0..win * s).map(|_| rng.normal()).collect(),
                ring_labels: (0..win).map(|_| rng.below(n_c as u32) as usize).collect(),
                ring_head: if win > 0 { rng.below(win as u32) as usize } else { 0 },
                ring_len,
                updates: rng.next_u64() >> 32,
                since_refactor: rng.below(8) as usize,
                refactors: u64::from(rng.below(100)),
            }
        });
        let has_solution = rng.below(4) != 0;
        let solution = has_solution.then(|| RidgeSolution {
            w_tilde: (0..n_c * s).map(|_| rng.normal()).collect(),
            s,
            ny: n_c,
            beta: 0.01,
            memory_words: rng.below(100_000) as usize,
        });
        let phase = if solution.is_some() {
            Phase::Serve
        } else {
            Phase::Collect
        };
        let err_cap = rng.below(6) as usize;
        let err_ring: Vec<bool> = (0..err_cap).map(|_| rng.below(2) == 1).collect();
        let err_len = if err_cap > 0 { rng.below(err_cap as u32 + 1) as usize } else { 0 };
        SessionSnapshot {
            id,
            phase,
            mask_nx: nx,
            mask_v: n_v,
            mask_m: (0..nx * n_v).map(|_| rng.sign()).collect(),
            buffer,
            new_since_train: rng.below(100) as usize,
            state_p: rng.uniform_in(0.1, 2.0),
            state_q: rng.uniform_in(0.1, 2.0),
            state_w: (0..n_c * nx * (nx + 1)).map(|_| rng.normal()).collect(),
            state_b: (0..n_c).map(|_| rng.normal()).collect(),
            solution,
            online,
            err_ring: err_ring.clone(),
            err_head: 0,
            err_len,
            err_count: err_ring[..err_len].iter().filter(|&&e| e).count(),
            rng_state: rng.next_u64(),
            rng_inc: rng.next_u64() | 1,
            epoch_losses: (0..rng.below(5)).map(|_| rng.uniform()).collect(),
            generation: u64::from(rng.below(50)),
            engine_generation: u64::from(rng.below(5)),
            gen_p: rng.uniform_in(0.1, 2.0),
            gen_q: rng.uniform_in(0.1, 2.0),
            obs_t_max: rng.below(64) as usize,
            obs_u_max: rng.uniform(),
            degraded: rng.below(2) == 1,
            quarantines: u64::from(rng.below(10)),
            mutations: rng.next_u64() >> 32,
        }
    }

    #[test]
    fn roundtrip_property() {
        let mut rng = Pcg32::seed(0xC0DE);
        for i in 0..200 {
            let snap = random_snapshot(&mut rng, i);
            let bytes = encode_session(&snap);
            let back = decode_session(&bytes).unwrap_or_else(|e| panic!("case {i}: {e}"));
            assert_eq!(snap, back, "case {i}");
        }
    }

    #[test]
    fn truncation_at_every_length_is_typed() {
        let mut rng = Pcg32::seed(0xBEEF);
        let snap = random_snapshot(&mut rng, 1);
        let bytes = encode_session(&snap);
        // every proper prefix must fail with a typed error — never panic
        for cut in 0..bytes.len() {
            let err = decode_session(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    CheckpointError::Truncated
                        | CheckpointError::CrcMismatch
                        | CheckpointError::BadMagic
                        | CheckpointError::Invalid(_)
                ),
                "cut {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn crc_tamper_detected_at_every_byte() {
        let mut rng = Pcg32::seed(0xF00D);
        let snap = random_snapshot(&mut rng, 2);
        let bytes = encode_session(&snap);
        // flip one bit in every post-magic byte: the CRC (or the magic /
        // version check) must catch it — decode never panics and never
        // silently returns wrong data equal to the original
        for i in 0..bytes.len() {
            let mut evil = bytes.clone();
            evil[i] ^= 0x40;
            match decode_session(&evil) {
                Err(_) => {}
                Ok(back) => assert_ne!(back, snap, "byte {i}: corruption went unnoticed"),
            }
        }
    }

    #[test]
    fn bad_version_and_magic_are_typed() {
        let mut rng = Pcg32::seed(0xDEAD);
        let snap = random_snapshot(&mut rng, 3);
        let mut bytes = encode_session(&snap);
        // bump the version byte and re-seal the CRC so ONLY the version
        // check can object
        bytes[4] = 99;
        let body_end = bytes.len() - 4;
        let crc = crc32(&bytes[4..body_end]);
        bytes[body_end..].copy_from_slice(&crc.to_le_bytes());
        assert_eq!(
            decode_session(&bytes).unwrap_err(),
            CheckpointError::BadVersion(99)
        );
        let mut bytes = encode_session(&snap);
        bytes[0] = b'X';
        assert_eq!(decode_session(&bytes).unwrap_err(), CheckpointError::BadMagic);
        assert_eq!(decode_session(&[]).unwrap_err(), CheckpointError::Truncated);
    }

    #[test]
    fn absurd_solution_dims_are_invalid_not_saturated() {
        // corruption-matrix case for the saturating_mul bug: a record
        // claiming s = ny = u32::MAX must decode to Invalid. On 64-bit
        // targets (2^32-1)^2 still fits usize, so the length-mismatch
        // check fires; on 32-bit targets checked_mul itself returns None.
        // The old saturating_mul compared against a clamped product —
        // on narrow targets a w_tilde of length usize::MAX would have
        // "matched" instead of being rejected as corrupt.
        let mut rng = Pcg32::seed(0x51ED);
        let mut snap = random_snapshot(&mut rng, 11);
        snap.solution = Some(RidgeSolution {
            w_tilde: vec![0.0; 4],
            s: u32::MAX as usize,
            ny: u32::MAX as usize,
            beta: 0.01,
            memory_words: 0,
        });
        let bytes = encode_session(&snap);
        match decode_session(&bytes) {
            Err(CheckpointError::Invalid(msg)) => {
                assert!(
                    msg.contains("overflow") || msg.contains("solution length"),
                    "{msg}"
                );
            }
            other => panic!("expected Invalid for absurd dims, got {other:?}"),
        }
    }

    #[test]
    fn writer_reads_back_and_dedupes_by_mutations() {
        let mut rng = Pcg32::seed(0xACED);
        let dir = std::env::temp_dir().join(format!("dfr-ckpt-test-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let cfg = CheckpointConfig {
            dir: dir.clone(),
            every: 2,
        };
        // hand-write two shard archives with an overlapping session id
        // at different freshness stamps
        let mut stale = random_snapshot(&mut rng, 7);
        stale.mutations = 5;
        let mut fresh = random_snapshot(&mut rng, 7);
        fresh.mutations = 9;
        let other = random_snapshot(&mut rng, 8);
        fs::create_dir_all(&dir).unwrap();
        fs::write(
            dir.join("shard-0.ckpt"),
            write_archive(&[
                Entry {
                    name: "session-7".into(),
                    data: encode_session(&stale),
                },
                Entry {
                    name: "session-8".into(),
                    data: encode_session(&other),
                },
            ])
            .unwrap(),
        )
        .unwrap();
        fs::write(
            dir.join("shard-1.ckpt"),
            write_archive(&[Entry {
                name: "session-7".into(),
                data: encode_session(&fresh),
            }])
            .unwrap(),
        )
        .unwrap();
        // plus one garbage archive that must be skipped, not fatal
        fs::write(dir.join("shard-2.ckpt"), b"not a zip at all").unwrap();
        let (snaps, corrupt) = load_all(&dir);
        assert_eq!(corrupt, 1);
        assert_eq!(snaps.len(), 2);
        let got7 = snaps.iter().find(|s| s.id == 7).unwrap();
        assert_eq!(got7.mutations, 9, "freshest snapshot must win");
        assert!(snaps.iter().any(|s| s.id == 8));
        // cadence counter
        let mut ck = ShardCheckpointer::new(&cfg, 0);
        assert!(!ck.note_mutation());
        assert!(ck.note_mutation());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_all_missing_dir_is_empty_not_error() {
        let (snaps, corrupt) = load_all(Path::new("/definitely/not/here"));
        assert!(snaps.is_empty());
        assert_eq!(corrupt, 0);
    }
}
