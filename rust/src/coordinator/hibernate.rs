//! Session hibernation: keep only the hot set resident, park the rest
//! on disk (DESIGN.md §16).
//!
//! The paper's target is an edge box serving *many mostly-idle*
//! deployments: per-session state is deliberately small (ring buffer,
//! packed Cholesky factor, generation counters — see
//! `SessionSnapshot`), so a session that has gone cold can be
//! serialized out and brought back later, bitwise-identically, for the
//! price of one disk round-trip. Each shard owns a
//! [`HibernationStore`] under `<dir>/shard-<i>/` and a
//! [`ShardHibernator`] policy head that decides *when* to park:
//!
//! - **capacity (LRU):** after every drain cycle the shard calls
//!   [`ShardHibernator::enforce_cap`]; while more than `max_resident`
//!   sessions are resident, the least-recently-touched one is
//!   snapshotted through the PR-7 checkpoint codec (`encode_session`,
//!   CRC-guarded) into the store and dropped from the map.
//! - **idle clock:** with `hibernate_after` set, the shard's `recv`
//!   gains a timeout; on each quiet tick
//!   [`ShardHibernator::sweep_idle`] parks every session idle longer
//!   than the threshold.
//!
//! Rehydration is touch-driven: before a drain batch is planned, any
//! requested session that is not resident but known to the store is
//! restored via `Session::restore` (the same path checkpoint recovery
//! uses), so the response stream of a session that hibernated is
//! **bitwise equal** to one that never left memory
//! (`tests/hibernation.rs`).
//!
//! # Store layout and the zip caps
//!
//! Snapshots live as `session-<id>` entries inside stored-zip archives
//! (`bucket-<b>.hib`), the same dependency-free container the
//! checkpoints use. The classic zip format caps an archive at 65 535
//! entries / 4 GiB — limits `zipstore::write_archive` now *refuses*
//! rather than truncates — so the store shards ids across `buckets`
//! archives by a mixed hash. Buckets also bound the rewrite cost of
//! one hibernate/take to `O(bucket size)`, not `O(fleet)`.
//!
//! # Interaction with checkpoints and supervision
//!
//! A session id must live in exactly one place. On restore (spawn or
//! supervisor respawn), ids present in both a checkpoint archive and
//! the hibernation store are resolved by
//! [`ShardHibernator::resolve_restore_conflict`]: the higher
//! `mutations` stamp wins and the hibernated copy is always removed
//! (ties keep the checkpoint copy — shutdown writes the final
//! checkpoint *before* `hibernate_all`, so equal stamps are the same
//! state). Checkpoint archives continue to cover only *resident*
//! sessions; a hibernated session's durable copy **is** its store
//! entry.

use std::collections::{BTreeMap, HashMap};
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::checkpoint::{decode_session, encode_session};
use super::session::{Session, SessionConfig, SessionSnapshot};
use crate::data::zipstore::{read_archive, write_archive, Entry};
use crate::log_warn;
use crate::util::metrics::{Counter, Gauge, Registry};
use crate::util::trace::{EventKind, EventLog};

/// Hibernation policy knobs (server-wide; each shard applies them to
/// its own session map).
#[derive(Clone, Debug)]
pub struct HibernateConfig {
    /// Store root; each shard writes under `<dir>/shard-<i>/`.
    pub dir: PathBuf,
    /// Per-shard resident-session cap; beyond it the least-recently
    /// touched sessions hibernate. `usize::MAX` disables the LRU cap.
    pub max_resident: usize,
    /// Park sessions idle longer than this (None disables the idle
    /// clock; the shard loop then keeps its plain blocking `recv`).
    pub hibernate_after: Option<Duration>,
    /// Archives per shard store. More buckets → smaller rewrite units
    /// and more headroom under the 65 535-entry zip cap.
    pub buckets: usize,
}

impl HibernateConfig {
    /// Cap/idle-clock both disabled; 64 buckets.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        HibernateConfig {
            dir: dir.into(),
            max_resident: usize::MAX,
            hibernate_after: None,
            buckets: 64,
        }
    }
}

fn invalid<E: std::fmt::Display>(e: E) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, e.to_string())
}

/// One shard's on-disk parking lot: sessions as `session-<id>` entries
/// spread over `bucket-<b>.hib` stored-zip archives, plus an in-memory
/// id index built by scanning the directory once at open.
pub struct HibernationStore {
    dir: PathBuf,
    buckets: usize,
    /// id → bucket it currently lives in (scan result for pre-existing
    /// entries, so a changed `buckets` knob never strands a session)
    index: BTreeMap<u64, usize>,
    /// archive mutations (rewrites + deletions) committed by this
    /// handle — the churn figure the eviction-batching test pins down
    rewrites: u64,
}

impl HibernationStore {
    /// Open (creating if absent) a shard's store and scan its bucket
    /// archives to index the parked ids. Returns the number of
    /// unreadable archives/entries skipped — corruption is counted,
    /// never fatal, matching `checkpoint::load_all`.
    pub fn open(root: &Path, shard: usize, buckets: usize) -> io::Result<(Self, u64)> {
        let dir = root.join(format!("shard-{shard}"));
        fs::create_dir_all(&dir)?;
        let mut index = BTreeMap::new();
        let mut corrupt = 0u64;
        for dirent in fs::read_dir(&dir)?.flatten() {
            let path = dirent.path();
            let Some(bucket) = bucket_of_path(&path) else {
                continue;
            };
            let Ok(bytes) = fs::read(&path) else {
                corrupt += 1;
                continue;
            };
            let entries = match read_archive(&bytes) {
                Ok(entries) => entries,
                Err(_) => {
                    corrupt += 1;
                    continue;
                }
            };
            for entry in entries {
                match entry.name.strip_prefix("session-").and_then(|s| s.parse().ok()) {
                    Some(id) => {
                        index.insert(id, bucket);
                    }
                    None => corrupt += 1,
                }
            }
        }
        Ok((
            HibernationStore {
                dir,
                buckets: buckets.max(1),
                index,
                rewrites: 0,
            },
            corrupt,
        ))
    }

    /// Which bucket a *new* entry for `id` goes to. The id is mixed
    /// first (splitmix64 finalizer) so the server's `id % shards`
    /// routing stride cannot skew the distribution.
    fn bucket_of(&self, id: u64) -> usize {
        let mut x = id.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        (x % self.buckets as u64) as usize
    }

    fn bucket_path(&self, bucket: usize) -> PathBuf {
        self.dir.join(format!("bucket-{bucket}.hib"))
    }

    /// Atomically rewrite one bucket archive (tmp + rename, like the
    /// checkpoint writer); an empty bucket is deleted instead.
    fn rewrite_bucket(&mut self, bucket: usize, entries: &[Entry]) -> io::Result<()> {
        let path = self.bucket_path(bucket);
        if entries.is_empty() {
            match fs::remove_file(&path) {
                Ok(()) => {
                    self.rewrites += 1;
                    return Ok(());
                }
                Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(()),
                Err(e) => return Err(e),
            }
        }
        let bytes = write_archive(entries).map_err(invalid)?;
        let tmp = path.with_extension("hib.tmp");
        fs::write(&tmp, &bytes)?;
        fs::rename(&tmp, &path)?;
        self.rewrites += 1;
        Ok(())
    }

    fn read_bucket(&self, bucket: usize) -> io::Result<Vec<Entry>> {
        match fs::read(self.bucket_path(bucket)) {
            Ok(bytes) => read_archive(&bytes).map_err(invalid),
            Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(Vec::new()),
            Err(e) => Err(e),
        }
    }

    /// Park one snapshot. On any error the store is unchanged (the
    /// caller keeps the session resident) — a failed rename leaves the
    /// previous bucket contents intact.
    pub fn hibernate(&mut self, snap: &SessionSnapshot) -> io::Result<()> {
        let bucket = match self.index.get(&snap.id) {
            Some(&b) => b,
            None => self.bucket_of(snap.id),
        };
        let mut entries = self.read_bucket(bucket)?;
        let name = format!("session-{}", snap.id);
        entries.retain(|e| e.name != name);
        entries.push(Entry {
            name,
            data: encode_session(snap),
        });
        self.rewrite_bucket(bucket, &entries)?;
        self.index.insert(snap.id, bucket);
        Ok(())
    }

    /// Park a batch of snapshots with **one archive rewrite per
    /// bucket** instead of one per session — the O(bucket) read +
    /// encode + rename is paid once for every evictee that hashes into
    /// it, so a cap-eviction burst of E sessions costs at most
    /// `min(E, buckets)` rewrites (`rewrites` counts them; the churn
    /// test in this module pins the bound).
    ///
    /// Returns the ids actually parked. A failing bucket skips only its
    /// own sessions — other buckets still commit, matching
    /// [`hibernate`](Self::hibernate)'s store-unchanged-on-error
    /// contract bucket by bucket. Errors are returned for the caller to
    /// count/log; a snapshot absent from the returned ids stays the
    /// caller's responsibility (keep it resident).
    pub fn hibernate_many(
        &mut self,
        snaps: &[SessionSnapshot],
    ) -> (Vec<u64>, Vec<io::Error>) {
        let mut by_bucket: BTreeMap<usize, Vec<&SessionSnapshot>> = BTreeMap::new();
        for snap in snaps {
            let bucket = match self.index.get(&snap.id) {
                Some(&b) => b,
                None => self.bucket_of(snap.id),
            };
            by_bucket.entry(bucket).or_default().push(snap);
        }
        let mut parked = Vec::with_capacity(snaps.len());
        let mut errors = Vec::new();
        for (bucket, group) in by_bucket {
            let commit = (|| -> io::Result<()> {
                let mut entries = self.read_bucket(bucket)?;
                for snap in &group {
                    let name = format!("session-{}", snap.id);
                    entries.retain(|e| e.name != name);
                    entries.push(Entry {
                        name,
                        data: encode_session(snap),
                    });
                }
                self.rewrite_bucket(bucket, &entries)
            })();
            match commit {
                Ok(()) => {
                    for snap in group {
                        self.index.insert(snap.id, bucket);
                        parked.push(snap.id);
                    }
                }
                Err(e) => errors.push(e),
            }
        }
        (parked, errors)
    }

    /// Archive mutations committed by this handle so far (rewrites and
    /// empty-bucket deletions) — eviction-churn observability.
    pub fn rewrites(&self) -> u64 {
        self.rewrites
    }

    /// Remove and return `id`'s snapshot. `Ok(None)` when the store
    /// does not hold it. The entry leaves the store even when its
    /// payload later fails to restore — a corrupt record must not be
    /// rehydrate-retried forever.
    pub fn take(&mut self, id: u64) -> io::Result<Option<SessionSnapshot>> {
        let Some(&bucket) = self.index.get(&id) else {
            return Ok(None);
        };
        let mut entries = self.read_bucket(bucket)?;
        let name = format!("session-{id}");
        let Some(pos) = entries.iter().position(|e| e.name == name) else {
            self.index.remove(&id);
            return Ok(None);
        };
        let entry = entries.swap_remove(pos);
        self.rewrite_bucket(bucket, &entries)?;
        self.index.remove(&id);
        let snap = decode_session(&entry.data).map_err(invalid)?;
        if snap.id != id {
            return Err(invalid(format!(
                "store entry {name} decodes to session {}",
                snap.id
            )));
        }
        Ok(Some(snap))
    }

    /// Is `id` parked here?
    pub fn contains(&self, id: u64) -> bool {
        self.index.contains_key(&id)
    }

    /// Number of parked sessions.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }
}

fn bucket_of_path(path: &Path) -> Option<usize> {
    let name = path.file_name()?.to_str()?;
    name.strip_prefix("bucket-")?.strip_suffix(".hib")?.parse().ok()
}

/// Per-shard hibernation policy head: owns the store, the LRU touch
/// clock, and the shard-labelled metric instruments.
pub struct ShardHibernator {
    store: HibernationStore,
    shard: usize,
    max_resident: usize,
    hibernate_after: Option<Duration>,
    /// monotonic touch stamp; higher = more recent
    clock: u64,
    /// resident id → (touch stamp, wall time of last touch)
    touch: HashMap<u64, (u64, Instant)>,
    hibernated_total: Arc<Counter>,
    rehydrated_total: Arc<Counter>,
    resident_gauge: Arc<Gauge>,
    hibernated_gauge: Arc<Gauge>,
    hibernate_errors: Arc<Counter>,
    rehydrate_errors: Arc<Counter>,
    /// operational journal for park/rehydrate transitions; `None` in
    /// unit tests that build a hibernator without a server around it
    events: Option<Arc<EventLog>>,
}

impl ShardHibernator {
    /// Open the shard's store and register its labelled instruments.
    /// Unreadable store archives count `rehydrate_errors_total` — the
    /// sessions inside are lost to the index, the server still starts.
    pub fn new(cfg: &HibernateConfig, shard: usize, metrics: &Registry) -> io::Result<Self> {
        let (store, corrupt) = HibernationStore::open(&cfg.dir, shard, cfg.buckets)?;
        let shard_label = shard.to_string();
        let labels: [(&str, &str); 1] = [("shard", shard_label.as_str())];
        let h = ShardHibernator {
            store,
            shard,
            max_resident: cfg.max_resident.max(1),
            hibernate_after: cfg.hibernate_after,
            clock: 0,
            touch: HashMap::new(),
            hibernated_total: metrics.counter_labelled("sessions_hibernated_total", &labels),
            rehydrated_total: metrics.counter_labelled("sessions_rehydrated_total", &labels),
            resident_gauge: metrics.gauge_labelled("resident_sessions", &labels),
            hibernated_gauge: metrics.gauge_labelled("hibernated_sessions", &labels),
            hibernate_errors: metrics.counter_labelled("hibernate_errors_total", &labels),
            rehydrate_errors: metrics.counter_labelled("rehydrate_errors_total", &labels),
            events: None,
        };
        if corrupt > 0 {
            h.rehydrate_errors.add(corrupt);
            log_warn!(
                "shard {shard}: {corrupt} corrupt hibernation record(s) under {:?}",
                cfg.dir
            );
        }
        h.hibernated_gauge.set(h.store.len() as i64);
        Ok(h)
    }

    /// Attach the server's event journal so park/rehydrate transitions
    /// land in `Request::Events` alongside shard deaths and generation
    /// rolls. Optional: library users (and the unit tests below) run
    /// without one.
    pub fn set_events(&mut self, events: Arc<EventLog>) {
        self.events = Some(events);
    }

    /// The shard loop's `recv_timeout` period when the idle clock is
    /// on: half the idle threshold (floored at 50 ms) keeps the sweep
    /// error under ~1.5× `hibernate_after` without busy-waking.
    pub fn sweep_interval(&self) -> Option<Duration> {
        self.hibernate_after
            .map(|d| (d / 2).max(Duration::from_millis(50)))
    }

    /// Record that `id` was touched by a request this cycle.
    pub fn note_touch(&mut self, id: u64) {
        self.clock += 1;
        self.touch.insert(id, (self.clock, Instant::now()));
    }

    /// Is `id` parked in this shard's store?
    pub fn knows(&self, id: u64) -> bool {
        self.store.contains(id)
    }

    /// Bring a parked session back. `None` means the store record was
    /// missing or failed to restore (counted `rehydrate_errors_total`);
    /// the caller then treats the id as a brand-new session.
    pub fn rehydrate(&mut self, id: u64, cfg: &SessionConfig) -> Option<Session> {
        let snap = match self.store.take(id) {
            Ok(Some(snap)) => snap,
            Ok(None) => return None,
            Err(e) => {
                self.rehydrate_errors.inc();
                log_warn!("shard {}: rehydrating session {id} failed: {e}", self.shard);
                return None;
            }
        };
        match Session::restore(snap, cfg.clone()) {
            Ok(sess) => {
                self.rehydrated_total.inc();
                self.hibernated_gauge.set(self.store.len() as i64);
                self.note_touch(id);
                if let Some(ev) = &self.events {
                    ev.push(
                        EventKind::HibernateRehydrate,
                        self.shard as u32,
                        id,
                        format!("{} still parked on this shard", self.store.len()),
                    );
                }
                Some(sess)
            }
            Err(e) => {
                self.rehydrate_errors.inc();
                self.hibernated_gauge.set(self.store.len() as i64);
                log_warn!(
                    "shard {}: dropping unrestorable hibernated session {id}: {e}",
                    self.shard
                );
                None
            }
        }
    }

    /// Resolve a checkpoint-vs-store collision at restore time: the
    /// higher `mutations` stamp wins, and the hibernated copy always
    /// leaves the store (an id lives in exactly one place). Ties keep
    /// the checkpoint copy — shutdown checkpoints before it parks, so
    /// equal stamps are the same bytes.
    pub fn resolve_restore_conflict(&mut self, snap: SessionSnapshot) -> SessionSnapshot {
        if !self.store.contains(snap.id) {
            return snap;
        }
        match self.store.take(snap.id) {
            Ok(Some(parked)) => {
                self.hibernated_gauge.set(self.store.len() as i64);
                if parked.mutations > snap.mutations {
                    parked
                } else {
                    snap
                }
            }
            Ok(None) => snap,
            Err(e) => {
                self.rehydrate_errors.inc();
                self.hibernated_gauge.set(self.store.len() as i64);
                log_warn!(
                    "shard {}: conflict check for session {} failed: {e}",
                    self.shard,
                    snap.id
                );
                snap
            }
        }
    }

    /// Park a set of resident sessions in one batched store call (one
    /// archive rewrite per *bucket* — see
    /// [`HibernationStore::hibernate_many`]). Successfully parked
    /// sessions leave the map; a failing bucket's sessions stay
    /// resident (each bucket failure counts `hibernate_errors_total`
    /// once). Returns how many parked.
    fn park_many(&mut self, sessions: &mut BTreeMap<u64, Session>, ids: &[u64]) -> usize {
        let snaps: Vec<SessionSnapshot> = ids
            .iter()
            .filter_map(|id| sessions.get(id).map(Session::snapshot))
            .collect();
        if snaps.is_empty() {
            return 0;
        }
        let (parked, errors) = self.store.hibernate_many(&snaps);
        for &id in &parked {
            sessions.remove(&id);
            self.touch.remove(&id);
            self.hibernated_total.inc();
            if let Some(ev) = &self.events {
                ev.push(
                    EventKind::HibernatePark,
                    self.shard as u32,
                    id,
                    format!("{} now parked on this shard", self.store.len()),
                );
            }
        }
        self.hibernated_gauge.set(self.store.len() as i64);
        for e in errors {
            self.hibernate_errors.inc();
            log_warn!("shard {}: batched hibernate failed for a bucket: {e}", self.shard);
        }
        parked.len()
    }

    /// LRU eviction down to `max_resident`: called after every drain
    /// cycle. Sessions never touched this process (e.g. restored at
    /// spawn and quiet since) rank coldest. The whole overflow is
    /// parked in **one** batched store call — a burst of E evictees
    /// costs at most `min(E, buckets)` archive rewrites, not E. Store
    /// trouble is not retried this cycle (the failing bucket's sessions
    /// simply stay resident until the next drain).
    pub fn enforce_cap(&mut self, sessions: &mut BTreeMap<u64, Session>) {
        let overflow = sessions.len().saturating_sub(self.max_resident);
        if overflow == 0 {
            return;
        }
        let mut by_cold: Vec<u64> = sessions.keys().copied().collect();
        by_cold.sort_by_key(|id| self.touch.get(id).map_or(0, |&(c, _)| c));
        by_cold.truncate(overflow);
        self.park_many(sessions, &by_cold);
    }

    /// Idle-clock sweep: park every session whose last touch is older
    /// than `hibernate_after` (one batched store call). No-op when the
    /// idle clock is off.
    pub fn sweep_idle(&mut self, sessions: &mut BTreeMap<u64, Session>) {
        let Some(after) = self.hibernate_after else {
            return;
        };
        let idle: Vec<u64> = sessions
            .keys()
            .filter(|id| {
                self.touch
                    .get(id)
                    .map_or(true, |&(_, at)| at.elapsed() >= after)
            })
            .copied()
            .collect();
        self.park_many(sessions, &idle);
    }

    /// Park everything (the shutdown drain marker): the shard has just
    /// written its final checkpoint, so ties at the next restore keep
    /// the checkpoint copy of anything that fails to park here.
    pub fn hibernate_all(&mut self, sessions: &mut BTreeMap<u64, Session>) {
        let ids: Vec<u64> = sessions.keys().copied().collect();
        self.park_many(sessions, &ids);
    }

    /// Publish the resident level (single writer: the owning shard).
    pub fn report_resident(&self, resident: usize) {
        self.resident_gauge.set(resident as i64);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dfr-hib-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn session_cfg() -> SessionConfig {
        let mut cfg = SessionConfig::new(2, 2, 8);
        cfg.train.nx = 6;
        cfg.train.epochs = 2;
        cfg
    }

    fn fresh_session(id: u64) -> Session {
        Session::new(id, session_cfg(), 0xFEED ^ id)
    }

    #[test]
    fn store_roundtrips_and_indexes() {
        let dir = tmpdir("roundtrip");
        let (mut store, corrupt) = HibernationStore::open(&dir, 0, 4).unwrap();
        assert_eq!(corrupt, 0);
        assert!(store.is_empty());
        for id in [3u64, 7, 11] {
            store.hibernate(&fresh_session(id).snapshot()).unwrap();
        }
        assert_eq!(store.len(), 3);
        assert!(store.contains(7));
        assert!(!store.contains(4));
        let snap = store.take(7).unwrap().unwrap();
        assert_eq!(snap.id, 7);
        assert_eq!(store.len(), 2);
        assert!(store.take(7).unwrap().is_none());
        // a reopened store rebuilds the index from the archives
        drop(store);
        let (store2, corrupt2) = HibernationStore::open(&dir, 0, 4).unwrap();
        assert_eq!(corrupt2, 0);
        assert_eq!(store2.len(), 2);
        assert!(store2.contains(3) && store2.contains(11));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rehibernate_replaces_not_duplicates() {
        let dir = tmpdir("replace");
        let (mut store, _) = HibernationStore::open(&dir, 0, 2).unwrap();
        let mut snap = fresh_session(5).snapshot();
        store.hibernate(&snap).unwrap();
        snap.mutations = 99;
        store.hibernate(&snap).unwrap();
        assert_eq!(store.len(), 1);
        let back = store.take(5).unwrap().unwrap();
        assert_eq!(back.mutations, 99);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_with_different_bucket_count_finds_entries() {
        // index maps ids to the bucket they actually live in, so a
        // changed `buckets` knob never strands old entries
        let dir = tmpdir("rebucket");
        let (mut store, _) = HibernationStore::open(&dir, 0, 16).unwrap();
        for id in 0..10u64 {
            store.hibernate(&fresh_session(id).snapshot()).unwrap();
        }
        drop(store);
        let (mut store2, _) = HibernationStore::open(&dir, 0, 2).unwrap();
        assert_eq!(store2.len(), 10);
        for id in 0..10u64 {
            assert_eq!(store2.take(id).unwrap().unwrap().id, id);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_bucket_is_counted_not_fatal() {
        let dir = tmpdir("corrupt");
        let (mut store, _) = HibernationStore::open(&dir, 0, 1).unwrap();
        store.hibernate(&fresh_session(1).snapshot()).unwrap();
        drop(store);
        fs::write(dir.join("shard-0").join("bucket-0.hib"), b"garbage").unwrap();
        let (store2, corrupt) = HibernationStore::open(&dir, 0, 1).unwrap();
        assert_eq!(corrupt, 1);
        assert_eq!(store2.len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_cap_parks_the_coldest() {
        let dir = tmpdir("lru");
        let metrics = Registry::default();
        let mut cfg = HibernateConfig::new(&dir);
        cfg.max_resident = 2;
        let mut h = ShardHibernator::new(&cfg, 0, &metrics).unwrap();
        let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
        for id in [1u64, 2, 3] {
            sessions.insert(id, fresh_session(id));
            h.note_touch(id);
        }
        // re-touch 1 so 2 is the coldest
        h.note_touch(1);
        h.enforce_cap(&mut sessions);
        assert_eq!(sessions.len(), 2);
        assert!(!sessions.contains_key(&2), "coldest must hibernate");
        assert!(h.knows(2));
        assert_eq!(metrics.counter_total("sessions_hibernated_total"), 1);
        // touching 2 again rehydrates it bit-for-bit
        let back = h.rehydrate(2, &session_cfg()).unwrap();
        assert_eq!(back.snapshot(), fresh_session(2).snapshot());
        assert_eq!(metrics.counter_total("sessions_rehydrated_total"), 1);
        assert!(!h.knows(2));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn cap_eviction_batches_bucket_rewrites() {
        let dir = tmpdir("churn");
        let metrics = Registry::default();
        let mut cfg = HibernateConfig::new(&dir);
        cfg.max_resident = 1;
        cfg.buckets = 2;
        let mut h = ShardHibernator::new(&cfg, 0, &metrics).unwrap();
        let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
        for id in 0..17u64 {
            sessions.insert(id, fresh_session(id));
            h.note_touch(id);
        }
        // id 16 is hottest and stays; the 16-session overflow parks in
        // one batched call
        h.enforce_cap(&mut sessions);
        assert_eq!(sessions.len(), 1);
        assert!(sessions.contains_key(&16));
        assert_eq!(metrics.counter_total("sessions_hibernated_total"), 16);
        // the whole burst cost at most one archive rewrite per bucket,
        // not one per evicted session
        assert!(
            h.store.rewrites() <= 2,
            "eviction churn: {} rewrites for 16 evictees over 2 buckets",
            h.store.rewrites()
        );
        // every batched-parked session still restores bit-for-bit
        for id in 0..16u64 {
            let back = h.rehydrate(id, &session_cfg()).unwrap();
            assert_eq!(back.snapshot(), fresh_session(id).snapshot());
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn conflict_resolution_prefers_higher_mutations() {
        let dir = tmpdir("conflict");
        let metrics = Registry::default();
        let cfg = HibernateConfig::new(&dir);
        let mut h = ShardHibernator::new(&cfg, 0, &metrics).unwrap();
        let mut parked = fresh_session(9).snapshot();
        parked.mutations = 10;
        h.store.hibernate(&parked).unwrap();
        // checkpoint copy staler → parked copy wins, store emptied
        let mut ckpt = fresh_session(9).snapshot();
        ckpt.mutations = 4;
        let won = h.resolve_restore_conflict(ckpt);
        assert_eq!(won.mutations, 10);
        assert!(!h.knows(9));
        // tie → checkpoint copy wins, store still emptied
        let mut parked2 = fresh_session(9).snapshot();
        parked2.mutations = 7;
        h.store.hibernate(&parked2).unwrap();
        let mut ckpt2 = fresh_session(9).snapshot();
        ckpt2.mutations = 7;
        ckpt2.quarantines = 42; // marker to tell the copies apart
        let won2 = h.resolve_restore_conflict(ckpt2);
        assert_eq!(won2.quarantines, 42);
        assert!(!h.knows(9));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn idle_sweep_parks_untouched_sessions() {
        let dir = tmpdir("idle");
        let metrics = Registry::default();
        let mut cfg = HibernateConfig::new(&dir);
        cfg.hibernate_after = Some(Duration::from_millis(1));
        let mut h = ShardHibernator::new(&cfg, 0, &metrics).unwrap();
        assert!(h.sweep_interval().unwrap() >= Duration::from_millis(50));
        let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
        sessions.insert(4, fresh_session(4));
        h.note_touch(4);
        std::thread::sleep(Duration::from_millis(5));
        h.sweep_idle(&mut sessions);
        assert!(sessions.is_empty());
        assert!(h.knows(4));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn hibernate_all_empties_the_map() {
        let dir = tmpdir("all");
        let metrics = Registry::default();
        let cfg = HibernateConfig::new(&dir);
        let mut h = ShardHibernator::new(&cfg, 3, &metrics).unwrap();
        let mut sessions: BTreeMap<u64, Session> = BTreeMap::new();
        for id in 0..5u64 {
            sessions.insert(id, fresh_session(id));
        }
        h.hibernate_all(&mut sessions);
        assert!(sessions.is_empty());
        assert_eq!(h.store.len(), 5);
        h.report_resident(sessions.len());
        assert_eq!(metrics.counter_total("resident_sessions"), 0);
        assert_eq!(metrics.counter_total("hibernated_sessions"), 5);
        let _ = fs::remove_dir_all(&dir);
    }
}
