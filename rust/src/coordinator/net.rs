//! TCP network edge: a dependency-light, blocking-accept,
//! thread-per-connection front over the coordinator.
//!
//! The edge speaks a length-prefixed binary framing over the
//! [`protocol`](super::protocol) wire codec:
//!
//! ```text
//! frame := magic "DF" (2 B) | version u8 (=1) | kind u8 | len u32 LE | payload
//! ```
//!
//! `kind` is 0 for request payloads and 1 for response payloads; `len`
//! counts payload bytes only, bounded by [`NetConfig::max_frame`] so a
//! hostile length prefix cannot force an allocation. The header is
//! parsed by the pure [`parse_frame_header`] so the bounds are unit
//! testable without a socket.
//!
//! Error surfaces are deliberately two-tier:
//!
//! * **frame-level** problems (bad magic, unknown version, oversized
//!   length) mean the byte stream can no longer be trusted to be
//!   aligned on frame boundaries — the connection is answered with a
//!   final [`Response::Rejected`] and closed;
//! * **payload-level** problems (a frame that arrived intact but whose
//!   payload fails [`decode_request`]) keep the connection open: the
//!   framing is still aligned, so the edge answers a typed
//!   [`Response::Rejected`] and reads the next frame.
//!
//! Requests are forwarded through [`Server::call_timeout`], so shard
//! backpressure and supervision failures
//! ([`CallError`](super::server::CallError)) become
//! wire-visible `Rejected("transport: …")` responses instead of hung
//! sockets. `Request::Shutdown` has no wire tag at all (the codec
//! refuses it) and the server additionally rejects it from every public
//! call path, so remote bytes can never inject a drain marker.
//!
//! The design is thread-per-connection on a nonblocking accept loop:
//! the intended deployment is an edge box with tens of clients, not a
//! C10K gateway, and blocking I/O keeps the code free of poll-loop
//! state machines (and of dependencies — the whole edge is `std::net`).

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::protocol::{
    decode_request, decode_response, encode_request, encode_response, Request, Response, WireError,
};
use super::server::Server;
use crate::log_warn;
use crate::util::metrics::{Counter, Gauge, Histogram};

/// First two bytes of every frame.
pub const FRAME_MAGIC: [u8; 2] = *b"DF";
/// Only framing version this build speaks.
pub const FRAME_VERSION: u8 = 1;
/// Frame carries a request payload.
pub const KIND_REQUEST: u8 = 0;
/// Frame carries a response payload.
pub const KIND_RESPONSE: u8 = 1;
/// Bytes in the fixed frame header.
pub const FRAME_HEADER_LEN: usize = 8;

/// Why a frame header was refused. Frame-level errors are terminal for
/// the connection: once framing is suspect the stream cannot be
/// realigned.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// First two bytes were not `"DF"`.
    BadMagic([u8; 2]),
    /// Version byte this build does not speak.
    BadVersion(u8),
    /// Kind byte outside `{request, response}`.
    BadKind(u8),
    /// Declared payload length exceeds the configured bound.
    Oversized { len: u32, max: u32 },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:?} (expected \"DF\")"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported frame version {v} (this build speaks 1)")
            }
            FrameError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the {max}-byte bound")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// Validate an 8-byte frame header and return `(kind, payload_len)`.
///
/// Pure so the framing bounds are testable without sockets; both the
/// server edge and [`Client`] go through this.
pub fn parse_frame_header(h: &[u8; FRAME_HEADER_LEN], max: u32) -> Result<(u8, u32), FrameError> {
    if h[0..2] != FRAME_MAGIC {
        return Err(FrameError::BadMagic([h[0], h[1]]));
    }
    if h[2] != FRAME_VERSION {
        return Err(FrameError::BadVersion(h[2]));
    }
    let kind = h[3];
    if kind != KIND_REQUEST && kind != KIND_RESPONSE {
        return Err(FrameError::BadKind(kind));
    }
    let len = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
    if len > max {
        return Err(FrameError::Oversized { len, max });
    }
    Ok((kind, len))
}

/// Wrap a payload in a frame header.
fn frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    out.extend_from_slice(&FRAME_MAGIC);
    out.push(FRAME_VERSION);
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Knobs for the network edge.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address; port 0 asks the OS for an ephemeral port (read it
    /// back with [`NetServer::local_addr`]).
    pub addr: String,
    /// Per-request budget handed to [`Server::call_timeout`]; on expiry
    /// the client sees `Rejected("transport: …")` rather than a stuck
    /// socket.
    pub call_timeout: Duration,
    /// Upper bound on a frame payload; matches the codec's own
    /// per-vector cap by default.
    pub max_frame: u32,
    /// Connections beyond this are answered with a framed `Rejected`
    /// and closed before a handler thread is spawned.
    pub max_conns: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            call_timeout: Duration::from_secs(5),
            max_frame: 1 << 24,
            max_conns: 1024,
        }
    }
}

/// Counter handles the edge touches on the hot path, resolved once at
/// bind time.
struct NetMetrics {
    connections: Arc<Counter>,
    conn_rejected: Arc<Counter>,
    requests: Arc<Counter>,
    frame_errors: Arc<Counter>,
    decode_errors: Arc<Counter>,
    active_gauge: Arc<Gauge>,
    latency: Arc<Histogram>,
}

/// The listening edge. Owns the accept thread and every per-connection
/// handler thread; dropping it (or calling [`NetServer::shutdown`])
/// stops the accept loop and joins all of them.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    workers: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `cfg.addr` and start serving requests against `server`.
    ///
    /// The accept loop runs nonblocking with a 10 ms stop-flag poll, so
    /// shutdown never hangs on a quiet listener. Each accepted
    /// connection gets its own handler thread; past `max_conns` the
    /// connection is refused with a framed [`Response::Rejected`].
    pub fn bind(server: Arc<Server>, cfg: NetConfig) -> io::Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let workers: Arc<Mutex<Vec<thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let net = Arc::new(NetMetrics {
            connections: server.metrics.counter("net_connections_total"),
            conn_rejected: server.metrics.counter("net_conn_rejected_total"),
            requests: server.metrics.counter("net_requests_total"),
            frame_errors: server.metrics.counter("net_frame_errors_total"),
            decode_errors: server.metrics.counter("net_decode_errors_total"),
            active_gauge: server.metrics.gauge("net_active_connections"),
            latency: server.metrics.histogram("net_request_latency"),
        });
        let accept = {
            let stop = Arc::clone(&stop);
            let workers = Arc::clone(&workers);
            thread::Builder::new()
                .name("dfr-net-accept".to_string())
                .spawn(move || accept_loop(listener, server, cfg, stop, workers, net))?
        };
        Ok(NetServer {
            addr,
            stop,
            accept: Some(accept),
            workers,
        })
    }

    /// The address actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, wake blocked reads via the stop flag, and join
    /// the accept thread plus every handler. Idempotent; also run by
    /// `Drop`. Does not shut the coordinator down — that stays the
    /// owner's [`Server::shutdown`] call.
    pub fn shutdown(&mut self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let drained = match self.workers.lock() {
            Ok(mut g) => std::mem::take(&mut *g),
            Err(p) => std::mem::take(&mut *p.into_inner()),
        };
        for h in drained {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    server: Arc<Server>,
    cfg: NetConfig,
    stop: Arc<AtomicBool>,
    workers: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    net: Arc<NetMetrics>,
) {
    // handler threads self-report here so the cap counts live
    // connections, not spawned-ever
    let active = Arc::new(AtomicUsize::new(0));
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
                continue;
            }
            Err(e) => {
                log_warn!("net: accept failed: {e}");
                thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        net.connections.inc();
        if active.load(Ordering::Relaxed) >= cfg.max_conns {
            net.conn_rejected.inc();
            refuse(stream, "server at connection capacity");
            continue;
        }
        active.fetch_add(1, Ordering::Relaxed);
        net.active_gauge.inc();
        let handle = {
            let server = Arc::clone(&server);
            let cfg = cfg.clone();
            let stop = Arc::clone(&stop);
            let net = Arc::clone(&net);
            let active = Arc::clone(&active);
            thread::Builder::new()
                .name("dfr-net-conn".to_string())
                .spawn(move || {
                    handle_conn(stream, &server, &cfg, &stop, &net);
                    active.fetch_sub(1, Ordering::Relaxed);
                    net.active_gauge.dec();
                })
        };
        match handle {
            Ok(h) => {
                let mut guard = match workers.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                // reap handlers that already returned so the vec tracks
                // live connections, not connection history
                guard.retain(|w| !w.is_finished());
                guard.push(h);
            }
            Err(e) => {
                active.fetch_sub(1, Ordering::Relaxed);
                net.active_gauge.dec();
                log_warn!("net: could not spawn connection handler: {e}");
            }
        }
    }
}

/// Best-effort framed rejection on a connection we will not serve.
fn refuse(mut stream: TcpStream, msg: &str) {
    if let Ok(payload) = encode_response(&Response::Rejected(msg.to_string())) {
        let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
        let _ = stream.write_all(&frame(KIND_RESPONSE, &payload));
    }
}

enum ReadOutcome {
    /// Buffer filled.
    Filled,
    /// Clean close on a frame boundary, or stop/IO error — either way
    /// the connection is done.
    Closed,
}

/// Fill `buf` from the stream, riding out read-timeout wakeups (used to
/// poll the stop flag). A clean EOF is only acceptable at offset 0 of a
/// header read (`eof_ok_at_start`) — anywhere else the peer hung up
/// mid-frame.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    eof_ok_at_start: bool,
) -> ReadOutcome {
    let mut at = 0usize;
    while at < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return ReadOutcome::Closed;
        }
        match stream.read(&mut buf[at..]) {
            Ok(0) => {
                if !(at == 0 && eof_ok_at_start) {
                    log_warn!("net: peer closed mid-frame at byte {at} of {}", buf.len());
                }
                return ReadOutcome::Closed;
            }
            Ok(n) => at += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
                ) => {}
            Err(_) => return ReadOutcome::Closed,
        }
    }
    ReadOutcome::Filled
}

fn write_response(stream: &mut TcpStream, resp: &Response) -> io::Result<()> {
    let payload = encode_response(resp)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))?;
    stream.write_all(&frame(KIND_RESPONSE, &payload))
}

fn handle_conn(
    mut stream: TcpStream,
    server: &Server,
    cfg: &NetConfig,
    stop: &AtomicBool,
    net: &NetMetrics,
) {
    // short read timeout so a blocked read re-checks the stop flag
    if stream.set_read_timeout(Some(Duration::from_millis(250))).is_err() {
        return;
    }
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let _ = stream.set_nodelay(true);
    let mut header = [0u8; FRAME_HEADER_LEN];
    loop {
        if let ReadOutcome::Closed = read_full(&mut stream, &mut header, stop, true) {
            return;
        }
        let (kind, len) = match parse_frame_header(&header, cfg.max_frame) {
            Ok(parsed) => parsed,
            Err(e) => {
                // framing is unrecoverable: answer once and close
                net.frame_errors.inc();
                let _ = write_response(&mut stream, &Response::Rejected(format!("frame: {e}")));
                return;
            }
        };
        if kind != KIND_REQUEST {
            net.frame_errors.inc();
            let _ = write_response(
                &mut stream,
                &Response::Rejected("frame: expected a request frame".to_string()),
            );
            return;
        }
        // len is bounded by max_frame, so this allocation is too
        let mut payload = vec![0u8; len as usize];
        if let ReadOutcome::Closed = read_full(&mut stream, &mut payload, stop, false) {
            return;
        }
        let started = Instant::now();
        let req = match decode_request(&payload) {
            Ok(req) => req,
            Err(e) => {
                // payload-level: framing is still aligned, keep serving
                net.decode_errors.inc();
                if write_response(&mut stream, &Response::Rejected(format!("decode: {e}")))
                    .is_err()
                {
                    return;
                }
                continue;
            }
        };
        net.requests.inc();
        let resp = match server.call_timeout(req, cfg.call_timeout) {
            Ok(resp) => resp,
            // queue saturation / shard death / timeout become
            // wire-visible rejections instead of silent drops
            Err(e) => Response::Rejected(format!("transport: {e}")),
        };
        let wrote = write_response(&mut stream, &resp);
        net.latency.record_secs(started.elapsed().as_secs_f64());
        if wrote.is_err() {
            return;
        }
    }
}

/// What a [`Client::call`] can fail with.
#[derive(Debug)]
pub enum ClientError {
    /// Socket-level failure (connect, read, write, premature close).
    Io(io::Error),
    /// The server's frame header was malformed or oversized.
    Frame(FrameError),
    /// The response payload failed the wire codec.
    Wire(WireError),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Frame(e) => write!(f, "frame: {e}"),
            ClientError::Wire(e) => write!(f, "wire: {e}"),
        }
    }
}

impl std::error::Error for ClientError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ClientError::Io(e) => Some(e),
            ClientError::Frame(e) => Some(e),
            ClientError::Wire(e) => Some(e),
        }
    }
}

/// Minimal blocking client for the framed protocol: one in-flight
/// request per connection, responses strictly ordered. Used by the CLI
/// example, the integration tests, and the bench driver.
pub struct Client {
    stream: TcpStream,
    max_frame: u32,
}

impl Client {
    /// Connect to a [`NetServer`].
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client {
            stream,
            max_frame: NetConfig::default().max_frame,
        })
    }

    /// Send one request and block for its response.
    pub fn call(&mut self, req: &Request) -> Result<Response, ClientError> {
        let payload = encode_request(req).map_err(ClientError::Wire)?;
        self.stream
            .write_all(&frame(KIND_REQUEST, &payload))
            .map_err(ClientError::Io)?;
        self.read_response()
    }

    /// Write raw bytes to the server without framing or encoding.
    /// Diagnostic/test aid: lets the robustness suites feed hostile
    /// byte streams through a real socket.
    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.stream.write_all(bytes)
    }

    /// Read one framed response (pairs with [`Client::send_raw`] when
    /// driving the wire by hand).
    pub fn read_response(&mut self) -> Result<Response, ClientError> {
        let mut header = [0u8; FRAME_HEADER_LEN];
        self.stream.read_exact(&mut header).map_err(ClientError::Io)?;
        let (kind, len) =
            parse_frame_header(&header, self.max_frame).map_err(ClientError::Frame)?;
        if kind != KIND_RESPONSE {
            return Err(ClientError::Frame(FrameError::BadKind(kind)));
        }
        let mut payload = vec![0u8; len as usize];
        self.stream.read_exact(&mut payload).map_err(ClientError::Io)?;
        decode_response(&payload).map_err(ClientError::Wire)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn header(magic: [u8; 2], version: u8, kind: u8, len: u32) -> [u8; FRAME_HEADER_LEN] {
        let mut h = [0u8; FRAME_HEADER_LEN];
        h[0] = magic[0];
        h[1] = magic[1];
        h[2] = version;
        h[3] = kind;
        h[4..8].copy_from_slice(&len.to_le_bytes());
        h
    }

    #[test]
    fn frame_header_roundtrips_through_the_parser() {
        let payload = vec![7u8; 13];
        let framed = frame(KIND_REQUEST, &payload);
        assert_eq!(framed.len(), FRAME_HEADER_LEN + 13);
        let mut h = [0u8; FRAME_HEADER_LEN];
        h.copy_from_slice(&framed[..FRAME_HEADER_LEN]);
        let (kind, len) = parse_frame_header(&h, 1 << 24).unwrap();
        assert_eq!((kind, len), (KIND_REQUEST, 13));
        assert_eq!(&framed[FRAME_HEADER_LEN..], &payload[..]);
    }

    #[test]
    fn bad_magic_version_kind_and_length_are_typed() {
        assert_eq!(
            parse_frame_header(&header(*b"ZZ", 1, 0, 0), 100),
            Err(FrameError::BadMagic(*b"ZZ"))
        );
        assert_eq!(
            parse_frame_header(&header(FRAME_MAGIC, 9, 0, 0), 100),
            Err(FrameError::BadVersion(9))
        );
        assert_eq!(
            parse_frame_header(&header(FRAME_MAGIC, 1, 5, 0), 100),
            Err(FrameError::BadKind(5))
        );
        assert_eq!(
            parse_frame_header(&header(FRAME_MAGIC, 1, 0, 101), 100),
            Err(FrameError::Oversized { len: 101, max: 100 })
        );
        // boundary: exactly max is fine
        assert!(parse_frame_header(&header(FRAME_MAGIC, 1, 1, 100), 100).is_ok());
    }

    #[test]
    fn frame_error_displays_name_the_problem() {
        let txt = FrameError::Oversized { len: 9, max: 4 }.to_string();
        assert!(txt.contains('9') && txt.contains('4'), "{txt}");
        assert!(FrameError::BadVersion(3).to_string().contains('3'));
    }
}
