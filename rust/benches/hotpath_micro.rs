//! Hot-path microbenchmarks — the §Perf working set (EXPERIMENTS.md).
//!
//! Covers every loop the profile says matters: the reservoir step, the
//! DPRR rank-1 push, the packed ridge rank-1 update, the in-place
//! Cholesky solve at paper scale (s = 931), the whole per-sample
//! forward, one truncated-BP step, and (when artifacts are built) the
//! per-call PJRT overhead of the step/forward artifacts.

mod common;

use dfr_edge::data::dataset::Sample;
use dfr_edge::dfr::backprop::{truncated_grads, OutputLayer};
use dfr_edge::dfr::dprr::DprrAccumulator;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{Nonlinearity, Reservoir};
use dfr_edge::linalg::ridge::{rank1_update_packed, RidgeAccumulator, RidgeMethod};
use dfr_edge::linalg::tri_len;
use dfr_edge::util::bench::{bb, Bencher};
use dfr_edge::util::prng::Pcg32;

fn main() {
    let mut b = Bencher::with_target_time(0.4);
    let mut rng = Pcg32::seed(0xBEEF);
    let nx = 30;
    let v = 12;
    let t = 29;

    let res = Reservoir {
        mask: Mask::random(nx, v, &mut rng),
        p: 0.2,
        q: 0.1,
        f: Nonlinearity::Linear { alpha: 1.0 },
    };
    let u: Vec<f32> = (0..t * v).map(|_| rng.normal()).collect();
    let sample = Sample { u: u.clone(), t, label: 3 };

    // reservoir step (Eq. 14 over 30 nodes)
    let j: Vec<f32> = (0..nx).map(|_| rng.normal()).collect();
    let mut x = vec![0.1f32; nx];
    b.bench("reservoir_step_nx30", || {
        res.step(bb(&mut x), bb(&j));
    });

    // DPRR rank-1 push
    let xa: Vec<f32> = (0..nx).map(|_| rng.normal()).collect();
    let xb: Vec<f32> = (0..nx).map(|_| rng.normal()).collect();
    let mut acc = DprrAccumulator::new(nx);
    b.bench("dprr_push_nx30", || {
        acc.push(bb(&xa), bb(&xb));
    });

    // full per-sample forward (jpvow shape)
    b.bench("forward_jpvow_t29", || res.forward(bb(&u), t));

    // truncated-BP gradients
    let out = OutputLayer::zeros(9, nx);
    let fwd = res.forward(&u, t);
    b.bench("truncated_grads_jpvow", || {
        truncated_grads(bb(&fwd), 3, 0.2, 0.1, res.f, bb(&out))
    });

    // packed ridge rank-1 update at paper scale (s = 931)
    let s_dim = nx * nx + nx + 1;
    let r_t: Vec<f32> = (0..s_dim).map(|_| rng.normal()).collect();
    let mut packed = vec![0.0f32; tri_len(s_dim)];
    b.bench("ridge_rank1_packed_s931", || {
        rank1_update_packed(bb(&mut packed), bb(&r_t));
    });

    // in-place Cholesky solve at paper scale
    let mut racc = RidgeAccumulator::new(s_dim, 9);
    for i in 0..64 {
        let r: Vec<f32> = (0..s_dim).map(|_| rng.normal()).collect();
        racc.accumulate(&r, i % 9);
    }
    let mut b_slow = Bencher::with_target_time(1.2);
    b_slow.bench("cholesky_solve_s931_ny9", || {
        racc.solve(0.5, RidgeMethod::Cholesky1d)
    });
    b_slow.bench("cholesky_buffered_s931_ny9", || {
        racc.solve(0.5, RidgeMethod::CholeskyBuffered)
    });

    // PJRT per-call overhead (needs artifacts)
    if let Ok(manifest) = dfr_edge::runtime::Manifest::load("artifacts") {
        if let Ok(prof) = manifest.profile("jpvow") {
            if let Ok(exec) = dfr_edge::runtime::DfrExecutor::new(prof) {
                let mask = Mask::random(nx, v, &mut rng);
                let x0 = vec![0.0f32; nx];
                let u_t: Vec<f32> = (0..v).map(|_| rng.normal()).collect();
                b.bench("pjrt_step_call", || {
                    exec.step(bb(&x0), bb(&u_t), &mask, 0.2, 0.1).unwrap()
                });
                b.bench("pjrt_forward_call_t29", || {
                    exec.forward(bb(&sample), &mask, 0.2, 0.1).unwrap()
                });
                b.bench("pjrt_features_call_t29", || {
                    exec.features(bb(&sample), &mask, 0.2, 0.1).unwrap()
                });
            }
        }
    } else {
        println!("(artifacts not built — skipping PJRT call benches)");
    }

    let mut all = Bencher::new();
    std::mem::swap(&mut all, &mut b);
    let mut rows: Vec<Vec<String>> = all
        .results()
        .iter()
        .map(|s| vec![s.name.clone(), format!("{:.6e}", s.median), format!("{:.6e}", s.mad)])
        .collect();
    rows.extend(b_slow.results().iter().map(|s| {
        vec![s.name.clone(), format!("{:.6e}", s.median), format!("{:.6e}", s.mad)]
    }));
    common::write_csv("hotpath_micro.csv", "name,median_s,mad_s", &rows);
}
