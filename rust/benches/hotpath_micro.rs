//! Hot-path microbenchmarks — the §Perf working set (EXPERIMENTS.md).
//!
//! Covers every loop the profile says matters: the reservoir step, the
//! DPRR rank-1 push, the packed ridge rank-1 update and its rank-k
//! blocked counterpart (B ∈ {1, 8, 32}), the whole per-sample forward
//! (allocating vs workspace), the batched multi-session forward at lane
//! depths 1/8/64 against the per-call workspace baseline, the in-place
//! Cholesky solve at paper scale
//! (s = 931), the β sweep (per-β clone vs shared workspace), one
//! truncated-BP step, the serial-vs-parallel ridge phase, and (when
//! artifacts are built) the per-call PJRT overhead.
//!
//! Besides the CSV, this bench writes `results/BENCH_hotpath.json`
//! pairing each optimized path with its baseline and the measured
//! speedup — the numbers quoted in DESIGN.md §Perf. Set
//! `DFR_BENCH_SMOKE=1` for a few-iteration CI smoke run.

mod common;

use dfr_edge::coordinator::{scores_from_r_tilde, scores_from_r_tilde_with};
use dfr_edge::data::dataset::Sample;
use dfr_edge::dfr::backprop::{truncated_grads, OutputLayer};
use dfr_edge::dfr::dprr::DprrAccumulator;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{BatchLane, BatchScratch, ForwardScratch, Nonlinearity, Reservoir};
use dfr_edge::dfr::train::{ridge_phase, TrainConfig};
use dfr_edge::linalg::ridge::{
    rank1_update_packed, RidgeAccumulator, RidgeMethod, SolveWorkspace, PAPER_BETAS,
};
use dfr_edge::linalg::tri_len;
use dfr_edge::simd::{Kernels, SimdMode};
use dfr_edge::util::bench::{bb, write_results_file, Bencher, Stats};
use dfr_edge::util::prng::Pcg32;

fn main() {
    let smoke = std::env::var("DFR_BENCH_SMOKE").as_deref() == Ok("1");
    let (fast_target, slow_target) = if smoke { (0.02, 0.05) } else { (0.4, 1.2) };
    let mut b = Bencher::with_target_time(fast_target);
    let mut rng = Pcg32::seed(0xBEEF);
    let nx = 30;
    let v = 12;
    let t = 29;

    let res = Reservoir {
        mask: Mask::random(nx, v, &mut rng),
        p: 0.2,
        q: 0.1,
        f: Nonlinearity::Linear { alpha: 1.0 },
    };
    let u: Vec<f32> = (0..t * v).map(|_| rng.normal()).collect();
    let sample = Sample { u: u.clone(), t, label: 3 };

    // reservoir step (Eq. 14 over 30 nodes)
    let j: Vec<f32> = (0..nx).map(|_| rng.normal()).collect();
    let mut x = vec![0.1f32; nx];
    b.bench("reservoir_step_nx30", || {
        res.step(bb(&mut x), bb(&j));
    });

    // DPRR rank-1 push
    let xa: Vec<f32> = (0..nx).map(|_| rng.normal()).collect();
    let xb: Vec<f32> = (0..nx).map(|_| rng.normal()).collect();
    let mut acc = DprrAccumulator::new(nx);
    b.bench("dprr_push_nx30", || {
        acc.push(bb(&xa), bb(&xb));
    });

    // full per-sample forward (jpvow shape): allocating vs workspace
    b.bench("forward_jpvow_t29", || res.forward(bb(&u), t));
    let mut fscratch = ForwardScratch::new(nx);
    b.bench("forward_scratch_jpvow_t29", || {
        res.forward_into(bb(&u), t, bb(&mut fscratch));
    });

    // batched multi-session forward: one node-major sweep over B lanes
    // vs B per-call `forward_into` passes (the baseline is
    // forward_scratch_jpvow_t29 — identical shape and op sequence, so
    // the delta is pure batching effect: shared time-step loop,
    // lane-contiguous accumulator rows)
    let lane_masks: Vec<Mask> = (0..64).map(|_| Mask::random(nx, v, &mut rng)).collect();
    let lane_us: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..t * v).map(|_| rng.normal()).collect())
        .collect();
    let mut bscratch = BatchScratch::new();
    for (name, depth) in [
        ("batched_forward_b1_t29", 1usize),
        ("batched_forward_b8_t29", 8),
        ("batched_forward_b64_t29", 64),
    ] {
        b.bench(name, || {
            bscratch.forward_batch_into(res.f, depth, |l| BatchLane {
                u: bb(&lane_us[l]),
                t,
                mask: &lane_masks[l],
                p: res.p,
                q: res.q,
            });
        });
    }

    // truncated-BP gradients
    let out = OutputLayer::zeros(9, nx);
    let fwd = res.forward(&u, t);
    b.bench("truncated_grads_jpvow", || {
        truncated_grads(bb(&fwd), 3, 0.2, 0.1, res.f, bb(&out))
    });

    // packed ridge Gram update at paper scale (s = 931): rank-1 per
    // sample vs rank-k blocks of 8 and 32 (same MAC count per sample;
    // the block reuses every triangle cache line B times)
    let s_dim = nx * nx + nx + 1;
    let r_t: Vec<f32> = (0..s_dim).map(|_| rng.normal()).collect();
    let mut packed = vec![0.0f32; tri_len(s_dim)];
    b.bench("ridge_rank1_packed_s931", || {
        rank1_update_packed(bb(&mut packed), bb(&r_t));
    });
    let mut gacc = RidgeAccumulator::new(s_dim, 9);
    for (name, bs) in [
        ("gram_block_b1_s931", 1usize),
        ("gram_block_b8_s931", 8),
        ("gram_block_b32_s931", 32),
    ] {
        let block: Vec<f32> = (0..bs * s_dim).map(|_| rng.normal()).collect();
        let labels: Vec<usize> = (0..bs).map(|i| i % 9).collect();
        b.bench(name, || {
            gacc.accumulate_block(bb(&block), bb(&labels));
        });
    }

    // explicit-SIMD kernel table vs the scalar reference (DESIGN.md
    // §18): the batched forward sweep (bitwise-equal class), the rank-k
    // Gram block and the score dots (tolerance-bounded class). Skipped
    // — null medians in the JSON — when the host lacks AVX2+FMA.
    let simd_table = Kernels::try_select(SimdMode::Force).ok();
    if let Some(k) = simd_table {
        for (name, depth) in [("simd_forward_b8_t29", 8usize), ("simd_forward_b64_t29", 64)] {
            b.bench(name, || {
                bscratch.forward_batch_into_with(
                    res.f,
                    depth,
                    |l| BatchLane {
                        u: bb(&lane_us[l]),
                        t,
                        mask: &lane_masks[l],
                        p: res.p,
                        q: res.q,
                    },
                    &k,
                );
            });
        }
        let block: Vec<f32> = (0..32 * s_dim).map(|_| rng.normal()).collect();
        let labels: Vec<usize> = (0..32).map(|i| i % 9).collect();
        let mut sacc = RidgeAccumulator::with_kernels(s_dim, 9, k);
        b.bench("simd_gram_block_b32_s931", || {
            sacc.accumulate_block(bb(&block), bb(&labels));
        });
    } else {
        println!("(no AVX2+FMA on this host — skipping simd kernel benches)");
    }
    // score dots at serving shape: scalar reference vs the SIMD table
    let w_tilde: Vec<f32> = (0..9 * s_dim).map(|_| rng.normal()).collect();
    let mut score_buf: Vec<f32> = Vec::new();
    b.bench("scores_dot_s931_ny9", || {
        scores_from_r_tilde(bb(&w_tilde), bb(&r_t), bb(&mut score_buf));
    });
    if let Some(k) = simd_table {
        b.bench("simd_scores_dot_s931_ny9", || {
            scores_from_r_tilde_with(bb(&w_tilde), bb(&r_t), bb(&mut score_buf), &k);
        });
    }

    // in-place Cholesky solve at paper scale + the β sweep both ways
    let mut racc = RidgeAccumulator::new(s_dim, 9);
    for i in 0..64 {
        let r: Vec<f32> = (0..s_dim).map(|_| rng.normal()).collect();
        racc.accumulate(&r, i % 9);
    }
    let mut b_slow = Bencher::with_target_time(slow_target);
    b_slow.bench("cholesky_solve_s931_ny9", || {
        racc.solve(0.5, RidgeMethod::Cholesky1d)
    });
    b_slow.bench("cholesky_buffered_s931_ny9", || {
        racc.solve(0.5, RidgeMethod::CholeskyBuffered)
    });
    b_slow.bench("beta_sweep_clone_s931", || {
        // the pre-workspace path: a fresh 1.7 MB triangle clone per β
        for &beta in &PAPER_BETAS {
            bb(racc.solve(beta, RidgeMethod::Cholesky1d));
        }
    });
    let mut sweep_ws = SolveWorkspace::new(s_dim, 9);
    b_slow.bench("beta_sweep_workspace_s931", || {
        for &beta in &PAPER_BETAS {
            bb(racc.solve_with_workspace(beta, RidgeMethod::Cholesky1d, bb(&mut sweep_ws)));
        }
    });

    // ridge phase end-to-end: serial vs parallel (features + β solves)
    let ds = common::bench_dataset("jpvow", 0x51D);
    let threads = common::threads();
    let mut cfg = TrainConfig { nx, ..Default::default() };
    let ridge_res = Reservoir {
        mask: Mask::random(nx, ds.n_v, &mut rng),
        p: 0.2,
        q: 0.1,
        f: cfg.f,
    };
    cfg.threads = 1;
    let serial_stats = b_slow
        .once("ridge_phase_serial_jpvow", || ridge_phase(&ds, &ridge_res, &cfg))
        .1
        .clone();
    cfg.threads = threads;
    let parallel_stats = b_slow
        .once(&format!("ridge_phase_parallel{threads}_jpvow"), || {
            ridge_phase(&ds, &ridge_res, &cfg)
        })
        .1
        .clone();

    // PJRT per-call overhead (needs artifacts)
    if let Ok(manifest) = dfr_edge::runtime::Manifest::load("artifacts") {
        if let Ok(prof) = manifest.profile("jpvow") {
            if let Ok(exec) = dfr_edge::runtime::DfrExecutor::new(prof) {
                let mask = Mask::random(nx, v, &mut rng);
                let x0 = vec![0.0f32; nx];
                let u_t: Vec<f32> = (0..v).map(|_| rng.normal()).collect();
                b.bench("pjrt_step_call", || {
                    exec.step(bb(&x0), bb(&u_t), &mask, 0.2, 0.1).unwrap()
                });
                b.bench("pjrt_forward_call_t29", || {
                    exec.forward(bb(&sample), &mask, 0.2, 0.1).unwrap()
                });
                b.bench("pjrt_features_call_t29", || {
                    exec.features(bb(&sample), &mask, 0.2, 0.1).unwrap()
                });
            }
        }
    } else {
        println!("(artifacts not built — skipping PJRT call benches)");
    }

    let mut stats: Vec<Stats> = b.results().to_vec();
    stats.extend_from_slice(b_slow.results());
    let rows: Vec<Vec<String>> = stats
        .iter()
        .map(|s| vec![s.name.clone(), format!("{:.6e}", s.median), format!("{:.6e}", s.mad)])
        .collect();
    common::write_csv("hotpath_micro.csv", "name,median_s,mad_s", &rows);

    // before/after pairs → results/BENCH_hotpath.json (DESIGN.md §Perf)
    let med = |name: &str| -> f64 {
        stats
            .iter()
            .find(|s| s.name.starts_with(name))
            .map(|s| s.median)
            .unwrap_or(f64::NAN)
    };
    let fwd_alloc = med("forward_jpvow_t29");
    let fwd_scratch = med("forward_scratch_jpvow_t29");
    let rank1 = med("ridge_rank1_packed_s931");
    let blk8 = med("gram_block_b8_s931") / 8.0;
    let blk32 = med("gram_block_b32_s931") / 32.0;
    let sweep_clone = med("beta_sweep_clone_s931");
    let sweep_ws_t = med("beta_sweep_workspace_s931");
    let bf1 = med("batched_forward_b1_t29");
    let bf8 = med("batched_forward_b8_t29") / 8.0;
    let bf64 = med("batched_forward_b64_t29") / 64.0;
    // simd block: measured pairs when the AVX2 table ran, nulls
    // otherwise (the committed snapshot's contract: simd ≥ 2× scalar
    // per lane on the b64 batched forward at jpvow scale)
    let simd_json = match simd_table {
        Some(k) => {
            let sf8 = med("simd_forward_b8_t29") / 8.0;
            let sf64 = med("simd_forward_b64_t29") / 64.0;
            let sg32 = med("simd_gram_block_b32_s931") / 32.0;
            let sc_scalar = med("scores_dot_s931_ny9");
            let sc_simd = med("simd_scores_dot_s931_ny9");
            format!(
                "\"simd\": {{\"table\": \"{}\", \"forward_b8_per_lane_s\": {sf8:.6e}, \"forward_b64_per_lane_s\": {sf64:.6e}, \"speedup_forward_b8\": {:.3}, \"speedup_forward_b64\": {:.3}, \"gram_block32_per_sample_s\": {sg32:.6e}, \"speedup_gram_b32\": {:.3}, \"scores_scalar_s\": {sc_scalar:.6e}, \"scores_simd_s\": {sc_simd:.6e}, \"speedup_scores\": {:.3}}}",
                k.name,
                bf8 / sf8,
                bf64 / sf64,
                blk32 / sg32,
                sc_scalar / sc_simd,
            )
        }
        None => "\"simd\": {\"table\": \"scalar\", \"forward_b8_per_lane_s\": null, \"forward_b64_per_lane_s\": null, \"speedup_forward_b8\": null, \"speedup_forward_b64\": null, \"gram_block32_per_sample_s\": null, \"speedup_gram_b32\": null, \"scores_scalar_s\": null, \"scores_simd_s\": null, \"speedup_scores\": null}".to_string(),
    };
    let json = format!(
        "{{\n  \"scale\": {{\"nx\": {nx}, \"s\": {s_dim}, \"t\": {t}, \"ny\": 9, \"threads\": {threads}, \"smoke\": {smoke}}},\n  \
         \"forward\": {{\"alloc_median_s\": {fwd_alloc:.6e}, \"scratch_median_s\": {fwd_scratch:.6e}, \"speedup\": {:.3}}},\n  \
         \"gram_accumulate\": {{\"rank1_per_sample_s\": {rank1:.6e}, \"block8_per_sample_s\": {blk8:.6e}, \"block32_per_sample_s\": {blk32:.6e}, \"speedup_b8\": {:.3}, \"speedup_b32\": {:.3}}},\n  \
         \"beta_sweep\": {{\"clone_median_s\": {sweep_clone:.6e}, \"workspace_median_s\": {sweep_ws_t:.6e}, \"speedup\": {:.3}}},\n  \
         \"batched_forward\": {{\"per_call_per_lane_s\": {fwd_scratch:.6e}, \"b1_per_lane_s\": {bf1:.6e}, \"b8_per_lane_s\": {bf8:.6e}, \"b64_per_lane_s\": {bf64:.6e}, \"speedup_b8\": {:.3}, \"speedup_b64\": {:.3}}},\n  \
         \"ridge_phase\": {{\"serial_s\": {:.6e}, \"parallel_s\": {:.6e}, \"speedup\": {:.3}}},\n  \
         {simd_json}\n}}\n",
        fwd_alloc / fwd_scratch,
        rank1 / blk8,
        rank1 / blk32,
        sweep_clone / sweep_ws_t,
        fwd_scratch / bf8,
        fwd_scratch / bf64,
        serial_stats.median,
        parallel_stats.median,
        serial_stats.median / parallel_stats.median,
    );
    write_results_file("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("→ results/BENCH_hotpath.json (copy to repo root to refresh the committed snapshot)");
}
