//! Quantized vs f32 datapath throughput at paper scale (jpvow shape:
//! Nx = 30, V = 12, T = 29, 9 classes, s = 931).
//!
//! The fixed-point engine exists for bit-accuracy (modelling what the
//! FPGA computes), not for software speed — integer ops with explicit
//! rounding/saturation typically run *slower* than the vectorized f32
//! hot path on a CPU. This bench quantifies that modelling overhead so
//! the engine choice is an informed one, and writes
//! `results/BENCH_quant.json` (committed snapshot at repo root
//! `BENCH_quant.json`). Set `DFR_BENCH_SMOKE=1` for a few-iteration CI
//! run.

use std::fmt::Write as _;

use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::data::dataset::Sample;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{ForwardScratch, Nonlinearity, Reservoir};
use dfr_edge::quant::{QFormat, QuantConfig, QuantEngine, QuantForwardScratch, QuantReservoir};
use dfr_edge::util::bench::{bb, write_results_file, Bencher};
use dfr_edge::util::prng::Pcg32;

fn main() {
    let smoke = std::env::var("DFR_BENCH_SMOKE").as_deref() == Ok("1");
    let target = if smoke { 0.02 } else { 0.4 };
    let mut b = Bencher::with_target_time(target);
    let mut rng = Pcg32::seed(0x9_0B17);
    let (nx, v, t, ny) = (30usize, 12usize, 29usize, 9usize);
    let mask = Mask::random(nx, v, &mut rng);
    let f = Nonlinearity::Linear { alpha: 1.0 };
    // inputs pre-scaled into the narrow formats' word range (the AXI
    // front-end shift — see quant::sweep); identical series for both
    // datapaths
    let u: Vec<f32> = (0..t * v).map(|_| 0.25 * rng.normal()).collect();
    let sample = Sample {
        u: u.clone(),
        t,
        label: 3,
    };
    let s_dim = nx * nx + nx + 1;
    let w_tilde: Vec<f32> = (0..ny * s_dim).map(|_| 0.01 * rng.normal()).collect();
    let (p, q) = (0.2f32, 0.1f32);

    // --- reservoir-level forward: f32 workspace vs quantized datapath
    let res = Reservoir {
        mask: mask.clone(),
        p,
        q,
        f,
    };
    let mut fs = ForwardScratch::new(nx);
    let fwd_f32 = b
        .bench("forward_f32_jpvow_t29", || {
            res.forward_into(bb(&u), t, bb(&mut fs));
        })
        .median;
    let mut fwd_by_format: Vec<(String, f64)> = Vec::new();
    for fmt in [QFormat::q4_12(), QFormat::q6_10(), QFormat::q8_8()] {
        let mut qres = QuantReservoir::new(
            mask.clone(),
            f,
            dfr_edge::quant::QArith::new(fmt),
            6,
        );
        qres.set_params(p, q);
        let mut qs = QuantForwardScratch::new(nx, v);
        let m = b
            .bench(&format!("forward_quant_{}_jpvow_t29", fmt.name()), || {
                qres.forward_into(bb(&u), t, bb(&mut qs));
            })
            .median;
        fwd_by_format.push((fmt.name(), m));
    }
    let fwd_quant = fwd_by_format[0].1;

    // --- engine-level infer (forward + output MAC + softmax)
    let native = NativeEngine::with_nonlinearity(nx, ny, f);
    let quant = QuantEngine::with_config(nx, ny, f, QuantConfig::with_format(QFormat::q4_12()));
    let mut scores = Vec::new();
    native
        .infer_into(&sample, &mask, p, q, &w_tilde, &mut scores)
        .unwrap();
    let inf_f32 = b
        .bench("infer_f32_jpvow_ny9", || {
            native
                .infer_into(bb(&sample), &mask, p, q, bb(&w_tilde), &mut scores)
                .unwrap();
        })
        .median;
    quant
        .infer_into(&sample, &mask, p, q, &w_tilde, &mut scores)
        .unwrap();
    let inf_quant = b
        .bench("infer_quant_q4_12_jpvow_ny9", || {
            quant
                .infer_into(bb(&sample), &mask, p, q, bb(&w_tilde), &mut scores)
                .unwrap();
        })
        .median;

    b.write_csv("quant_datapath.csv").expect("write csv");

    let mut fmt_rows = String::new();
    for (i, (name, m)) in fwd_by_format.iter().enumerate() {
        let _ = write!(
            fmt_rows,
            "    {{\"format\": \"{name}\", \"forward_median_s\": {m:.6e}}}{}",
            if i + 1 < fwd_by_format.len() { ",\n" } else { "" }
        );
    }
    let json = format!(
        "{{\n  \"scale\": {{\"nx\": {nx}, \"v\": {v}, \"t\": {t}, \"ny\": {ny}, \"s\": {s_dim}, \"smoke\": {smoke}}},\n  \
         \"forward\": {{\"f32_median_s\": {fwd_f32:.6e}, \"quant_median_s\": {fwd_quant:.6e}, \"quant_over_f32\": {:.3}}},\n  \
         \"infer\": {{\"f32_median_s\": {inf_f32:.6e}, \"quant_median_s\": {inf_quant:.6e}, \"quant_over_f32\": {:.3}}},\n  \
         \"formats\": [\n{fmt_rows}\n  ]\n}}\n",
        fwd_quant / fwd_f32,
        inf_quant / inf_f32,
    );
    write_results_file("BENCH_quant.json", &json).expect("write BENCH_quant.json");
    println!(
        "forward: f32 {fwd_f32:.3e} s vs quant {fwd_quant:.3e} s ({:.2}x); \
         infer: f32 {inf_f32:.3e} s vs quant {inf_quant:.3e} s ({:.2}x)",
        fwd_quant / fwd_f32,
        inf_quant / inf_f32,
    );
    println!("→ results/BENCH_quant.json (copy to repo root to refresh the committed snapshot)");
}
