//! Shared helpers for the bench targets (harness = false).
//!
//! The paper's evaluation runs full-size datasets for hours; the bench
//! suite reproduces each table/figure's *shape* on subsampled datasets so
//! `cargo bench` completes in minutes. `DFR_BENCH_FULL=1` lifts the caps
//! (used for the EXPERIMENTS.md numbers).

use dfr_edge::data::dataset::Dataset;
use dfr_edge::data::{profiles::Profile, synth};

/// Subsample caps for bench mode.
pub const BENCH_TRAIN_CAP: usize = 160;
pub const BENCH_TEST_CAP: usize = 160;

pub fn full_mode() -> bool {
    std::env::var("DFR_BENCH_FULL").as_deref() == Ok("1")
}

/// Dataset for bench runs: full shape statistics, subsampled counts.
pub fn bench_dataset(name: &str, seed: u64) -> Dataset {
    let prof = Profile::by_name(name).expect("profile");
    let mut ds = synth::generate(prof, seed);
    if !full_mode() {
        ds.train.truncate(BENCH_TRAIN_CAP);
        ds.test.truncate(BENCH_TEST_CAP);
    }
    ds
}

/// Threads for parallel sweeps.
pub fn threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// CSV writer helper: rows of stringy cells.
pub fn write_csv(file: &str, header: &str, rows: &[Vec<String>]) {
    let mut s = String::from(header);
    s.push('\n');
    for r in rows {
        s.push_str(&r.join(","));
        s.push('\n');
    }
    dfr_edge::util::bench::write_results_file(file, &s).expect("write results");
    println!("→ results/{file}");
}
