//! Table 8: ridge-regression memory, Gaussian elimination vs in-place
//! 1-D Cholesky, per dataset — plus the accuracy-equality check the
//! paper reports (both methods must classify identically).

mod common;

use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::train::{ridge_phase_from_features, TrainConfig};
use dfr_edge::dfr::reservoir::{Nonlinearity, Reservoir};
use dfr_edge::data::profiles::PROFILES;
use dfr_edge::linalg::counters::{memory_words_naive, memory_words_proposed};
use dfr_edge::linalg::ridge::RidgeMethod;
use dfr_edge::util::prng::Pcg32;

fn main() {
    println!("# Table 8 — ridge regression memory (naive vs proposed)\n");
    println!(
        "{:<8} {:>10} {:>10} {:>9} {:>12} {:>12}",
        "dataset", "acc naive", "acc prop.", "ratio", "naive words", "prop. words"
    );
    let nx = 30;
    let s = nx * nx + nx + 1;
    let mut rows = Vec::new();
    // accuracy equality measured on a subsampled problem per dataset
    for p in &PROFILES {
        let naive = memory_words_naive(s, p.n_c);
        let prop = memory_words_proposed(s, p.n_c);
        let ratio = naive as f64 / prop as f64;

        // measure accuracy with both methods on the same features
        let ds = common::bench_dataset(p.name, 42);
        let mut rng = Pcg32::seed(7);
        let res = Reservoir {
            mask: Mask::random(nx, ds.n_v, &mut rng),
            p: 0.2,
            q: 0.1,
            f: Nonlinearity::Linear { alpha: 1.0 },
        };
        let feats: Vec<(Vec<f32>, usize)> = ds
            .train
            .iter()
            .map(|smp| (res.forward(&smp.u, smp.t).r_tilde(), smp.label))
            .collect();
        let acc_of = |method: RidgeMethod| -> f64 {
            let cfg = TrainConfig {
                ridge_method: method,
                ..Default::default()
            };
            let sol = ridge_phase_from_features(&feats, ds.n_c, &cfg);
            let ok = ds
                .test
                .iter()
                .filter(|smp| {
                    sol.predict_class(&res.forward(&smp.u, smp.t).r_tilde()) == smp.label
                })
                .count();
            ok as f64 / ds.test.len() as f64
        };
        let a_naive = acc_of(RidgeMethod::Gaussian);
        let a_prop = acc_of(RidgeMethod::Cholesky1d);
        println!(
            "{:<8} {:>10.3} {:>10.3} {:>9.2} {:>12} {:>12}",
            p.name, a_naive, a_prop, ratio, naive, prop
        );
        assert!(
            (a_naive - a_prop).abs() < 0.02,
            "{}: methods disagree ({a_naive} vs {a_prop})",
            p.name
        );
        rows.push(vec![
            p.name.to_string(),
            format!("{a_naive:.4}"),
            format!("{a_prop:.4}"),
            naive.to_string(),
            prop.to_string(),
            format!("{ratio:.2}"),
        ]);
    }
    common::write_csv(
        "table8_ridge_mem.csv",
        "dataset,acc_naive,acc_proposed,naive_words,proposed_words,ratio",
        &rows,
    );
    println!("\n(paper: ratio ≈ 3.66–3.99 across datasets; identical accuracy)");
}
