//! Online reservoir adaptation vs retrain-from-scratch: the per-sample
//! cost of the Serve-phase adaptation loop (ridge fold + re-solve +
//! truncated-BPTT step), the cost of a full generation roll
//! (recalibrate → re-featurize the ring → reseed the factor), and the
//! recovery-from-drift latency both strategies pay — adaptation answers
//! every labelled sample in O(s²)+O(forward) and rolls generations
//! incrementally, while the batch strategy re-runs the whole §4.1
//! pipeline (25-epoch SGD + β-swept ridge) per `retrain_after` batch.
//!
//! Writes `results/BENCH_adapt.json` (the repo-root `BENCH_adapt.json`
//! is the committed snapshot; medians are filled by the driver image's
//! first run). Set `DFR_BENCH_SMOKE=1` for a few-iteration CI run.

use std::fmt::Write as _;

use dfr_edge::coordinator::engine::NativeEngine;
use dfr_edge::coordinator::session::{FeedOutcome, Session, SessionConfig};
use dfr_edge::data::dataset::Dataset;
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::util::bench::{write_results_file, Bencher};

fn dataset(train: usize, t: usize, seed: u64) -> Dataset {
    let prof = Profile {
        name: "bench",
        n_v: 4,
        n_c: 4,
        train,
        test: 16,
        t_min: t,
        t_max: t,
    };
    synth::generate_with(
        &prof,
        synth::SynthConfig {
            noise: 0.4,
            freq_sep: 0.1,
            ar: 0.4,
        },
        seed,
    )
}

fn session_config(nx: usize, epochs: usize, collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(4, 4, collect);
    scfg.train.nx = nx;
    scfg.train.epochs = epochs;
    scfg.train.res_decay_epochs = vec![epochs / 3, 2 * epochs / 3];
    scfg.train.out_decay_epochs = vec![epochs / 2];
    scfg.train.window = Some(64);
    scfg.buffer_cap = collect.max(64);
    scfg
}

fn trained_session(cfg: SessionConfig, eng: &NativeEngine, ds: &Dataset) -> Session {
    let streaming = cfg.train.window.is_some() || cfg.train.forgetting.is_some();
    let mut sess = Session::new(1, cfg, 0xADA9);
    for s in &ds.train {
        sess.feed_labelled(eng, s.clone()).unwrap();
    }
    assert_eq!(sess.online().is_some(), streaming, "unexpected serve path");
    sess
}

fn main() {
    let smoke = std::env::var("DFR_BENCH_SMOKE").as_deref() == Ok("1");
    // paper-ish scale vs smoke: reservoir size drives the forward +
    // O(s²) fold cost (s = Nx² + Nx + 1)
    let (nx, t, train, epochs, target) = if smoke {
        (10usize, 12usize, 80usize, 4usize, 0.02)
    } else {
        (30usize, 29usize, 200usize, 25usize, 0.5)
    };
    let ds = dataset(train, t, 0xADA7);
    let eng = NativeEngine::new(nx, 4);
    let mut b = Bencher::with_target_time(target);

    // --- streaming observe, adaptation OFF (baseline: fold + re-solve)
    let mut sess = trained_session(session_config(nx, epochs, train), &eng, &ds);
    let mut i = 0usize;
    let observe = b
        .bench(&format!("observe_noadapt_nx{nx}"), || {
            let out = sess
                .feed_labelled(&eng, ds.train[i % ds.train.len()].clone())
                .unwrap();
            assert!(matches!(out, FeedOutcome::Observed { .. }));
            i += 1;
        })
        .median;

    // --- streaming observe, adaptation ON, below the drift threshold
    // (fold + re-solve + truncated-BPTT step)
    let mut cfg = session_config(nx, epochs, train);
    cfg.adapt_reservoir = true;
    cfg.adapt_lr = 1e-4;
    cfg.adapt_drift_eps = 1e9; // steady state: never roll mid-bench
    let mut sess = trained_session(cfg, &eng, &ds);
    let mut i = 0usize;
    let adapt_observe = b
        .bench(&format!("observe_adapt_nx{nx}"), || {
            let out = sess
                .feed_labelled(&eng, ds.train[i % ds.train.len()].clone())
                .unwrap();
            assert!(matches!(
                out,
                FeedOutcome::Observed {
                    reservoir_step: true,
                    ..
                }
            ));
            i += 1;
        })
        .median;

    // --- a full generation roll per feed (recalibrate + re-featurize
    // the 64-sample ring + reseed + solve): the drift-recovery step
    let mut cfg = session_config(nx, epochs, train);
    cfg.adapt_reservoir = true;
    cfg.adapt_lr = 1e-4;
    cfg.adapt_drift_eps = -1.0; // every feed crosses the threshold
    let mut sess = trained_session(cfg, &eng, &ds);
    let mut i = 0usize;
    let reseed = b
        .bench(&format!("generation_roll_nx{nx}_w64"), || {
            let out = sess
                .feed_labelled(&eng, ds.train[i % ds.train.len()].clone())
                .unwrap();
            assert!(matches!(out, FeedOutcome::Adapted { .. }));
            i += 1;
        })
        .median;

    // --- retrain-from-scratch recovery: re-run the whole §4.1 batch
    // pipeline over the session's buffer (what a drift-triggered
    // `retrain_after` / error-rate fallback pays per recovery)
    let mut cfg = session_config(nx, epochs, train);
    cfg.train.window = None; // batch path
    let mut sess = trained_session(cfg, &eng, &ds);
    let retrain = b
        .bench(&format!("batch_retrain_nx{nx}"), || {
            let out = sess.finalize(&eng).unwrap();
            assert!(matches!(out, FeedOutcome::Trained { .. }));
        })
        .median;

    let speedup_observe = retrain / adapt_observe;
    let speedup_roll = retrain / reseed;
    println!(
        "observe {observe:.3e} s | +adapt {adapt_observe:.3e} s | generation roll {reseed:.3e} s \
         | batch retrain {retrain:.3e} s"
    );
    println!(
        "adaptation-on recovery: {speedup_observe:.1}× per sample, {speedup_roll:.1}× per \
         generation roll vs retrain-from-scratch"
    );

    b.write_csv("online_adaptation.csv").expect("write csv");
    let mut json = String::new();
    let _ = write!(
        json,
        "{{\n  \"scale\": {{\"nx\": {nx}, \"t\": {t}, \"train\": {train}, \"epochs\": {epochs}, \
         \"window\": 64, \"smoke\": {smoke}}},\n  \
         \"observe_median_s\": {observe:.6e},\n  \
         \"adapt_observe_median_s\": {adapt_observe:.6e},\n  \
         \"generation_roll_median_s\": {reseed:.6e},\n  \
         \"batch_retrain_median_s\": {retrain:.6e},\n  \
         \"adapt_vs_retrain_speedup\": {speedup_observe:.3},\n  \
         \"roll_vs_retrain_speedup\": {speedup_roll:.3}\n}}\n"
    );
    write_results_file("BENCH_adapt.json", &json).expect("write BENCH_adapt.json");
    println!("→ results/BENCH_adapt.json (copy to repo root to refresh the committed snapshot)");
}
