//! Table 11 (+ Fig. 10): synthesis-configuration Pareto front and the
//! write-buffer ablation.
//!
//! Shape targets: non-pipelined < standard < inlined in area;
//! inlined < standard < non-pipelined in calc time; and the RegSize
//! sweep shows the Algorithm-5 buffer collapsing the substitution II
//! (Fig. 10's story).

mod common;

use dfr_edge::data::profiles::Profile;
use dfr_edge::fpga::design::{DesignConfig, SystemModel};
use dfr_edge::fpga::schedule::{accumulation_ii, ridge_solve_cycles, ScheduleConfig, ShapeParams};
use dfr_edge::report;

fn main() {
    let prof = Profile::by_name("jpvow").unwrap();
    let shape = ShapeParams::new(30, prof.n_v as u64, prof.n_c as u64, prof.t_max as u64);
    let (n_train, epochs, n_betas, n_test) =
        (prof.train as u64, 25u64, 1u64, prof.test as u64);

    println!("# Table 11 — synthesis configurations\n");
    println!(
        "{}",
        report::table11_markdown(shape, n_train, epochs, n_betas, n_test)
    );

    let mut rows = Vec::new();
    for cfg in [
        DesignConfig::NonPipelined,
        DesignConfig::Standard,
        DesignConfig::Inlined,
    ] {
        let r = SystemModel::new(shape, cfg).report(n_train, epochs, n_betas, n_test);
        rows.push(vec![
            r.name.to_string(),
            r.resources.lut.to_string(),
            r.resources.ff.to_string(),
            format!("{:.1}", r.resources.bram36),
            r.resources.dsp.to_string(),
            format!("{:.3}", r.power_w),
            format!("{:.3}", r.calc_s()),
            format!("{:.3}", r.energy_j),
        ]);
    }
    common::write_csv(
        "table11_configs.csv",
        "config,lut,ff,bram,dsp,power_w,calc_s,energy_j",
        &rows,
    );

    // Fig. 10 ablation: RegSize vs substitution II and ridge-solve time
    println!("## Fig. 10 ablation — write-buffer depth (RegSize)\n");
    println!(
        "{:>8} {:>4} {:>16} {:>12}",
        "RegSize", "II", "solve cycles", "solve ms"
    );
    let mut arows = Vec::new();
    for reg in [1u32, 2, 4, 8] {
        let cfg = ScheduleConfig {
            pipelined: true,
            reg_size: reg,
            inline_state_update: false,
        };
        let ii = accumulation_ii(reg);
        let cycles = ridge_solve_cycles(&shape, &cfg);
        println!(
            "{:>8} {:>4} {:>16} {:>12.2}",
            reg,
            ii,
            cycles,
            cycles as f64 / 100e6 * 1e3
        );
        arows.push(vec![
            reg.to_string(),
            ii.to_string(),
            cycles.to_string(),
            format!("{:.3}", cycles as f64 / 100e6 * 1e3),
        ]);
    }
    common::write_csv(
        "fig10_regsize_ablation.csv",
        "reg_size,ii,solve_cycles,solve_ms",
        &arows,
    );
    println!("\n(paper: RegSize=4 chosen; naive RMW loop cannot reach II=1 — Fig. 10)");
}
