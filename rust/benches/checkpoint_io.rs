//! Checkpoint I/O bench — the cost of durability (DESIGN.md §15).
//!
//! Three stages per fleet size N ∈ {1, 8, 64} mid-stream sessions:
//!
//!   * `encode_n{N}`   — pure codec: session state → CRC-guarded record
//!     (what every cadence tick pays before touching the filesystem);
//!   * `snapshot_n{N}` — the full shard checkpoint write: encode every
//!     session, pack the stored-zip archive, write `*.tmp`, `rename`
//!     (what `--checkpoint-every` adds to the serve loop);
//!   * `restore_n{N}`  — `load_all` + `Session::restore` for the whole
//!     fleet (what `Server::spawn` / a supervisor respawn pays).
//!
//! A `train_one` row measures the alternative to durability: rebuilding
//! one session by re-running its batch training from the raw buffer.
//! The acceptance contract (committed in the repo-root
//! `BENCH_checkpoint.json`) is that per-session restore is at least 10×
//! cheaper than retraining — otherwise checkpoint/rehydrate would be
//! pointless and the supervisor should just retrain on respawn.
//!
//! Writes `results/BENCH_checkpoint.json` (the repo-root copy is the
//! committed snapshot). `DFR_BENCH_SMOKE=1` shrinks the sweep for CI.

use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;

use dfr_edge::coordinator::checkpoint::{encode_session, load_all, CheckpointConfig, ShardCheckpointer};
use dfr_edge::coordinator::engine::{Engine, NativeEngine};
use dfr_edge::coordinator::{Session, SessionConfig};
use dfr_edge::data::dataset::Sample;
use dfr_edge::util::bench::{write_results_file, Bencher};
use dfr_edge::util::prng::Pcg32;

const N_V: usize = 4;
const N_C: usize = 3;
const NX: usize = 16;
const T: usize = 40;
const COLLECT: usize = 24;
const WINDOW: usize = 32;
const STREAMED: usize = 48;

fn session_config() -> SessionConfig {
    let mut cfg = SessionConfig::new(N_V, N_C, COLLECT);
    cfg.train.nx = NX;
    cfg.train.epochs = 2;
    cfg.train.res_decay_epochs = vec![1];
    cfg.train.out_decay_epochs = vec![1];
    // single β: the bench measures checkpoint I/O, not model selection
    cfg.train.betas = vec![1e-2];
    cfg.train.window = Some(WINDOW);
    cfg
}

fn sample(rng: &mut Pcg32) -> Sample {
    Sample {
        u: (0..T * N_V).map(|_| rng.normal()).collect(),
        t: T,
        label: rng.below(N_C as u32) as usize,
    }
}

/// A session in the state worth checkpointing: trained, with a warm
/// sliding-window factor and a partially filled fallback ring.
fn build_session(id: u64, engine: &dyn Engine, samples: &[Sample]) -> Session {
    let mut sess = Session::new(id, session_config(), 0xFEED);
    for s in samples.iter().take(COLLECT + STREAMED) {
        sess.feed_labelled(engine, s.clone())
            .expect("bench session feed");
    }
    sess
}

fn main() {
    let smoke = std::env::var("DFR_BENCH_SMOKE").as_deref() == Ok("1");
    let (fleet_sizes, target): (&[usize], f64) = if smoke {
        (&[1, 8], 0.02)
    } else {
        (&[1, 8, 64], 0.2)
    };
    let mut b = Bencher::with_target_time(target);
    let mut rng = Pcg32::seed(0xC4EC);
    let max_fleet = *fleet_sizes.iter().max().unwrap();
    let samples: Vec<Sample> = (0..COLLECT + STREAMED).map(|_| sample(&mut rng)).collect();
    let engine = NativeEngine::new(NX, N_C);

    let dir = PathBuf::from(format!(
        "{}/dfr-bench-ckpt-{}",
        std::env::temp_dir().display(),
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    let ckpt_cfg = CheckpointConfig {
        dir: dir.clone(),
        every: 1,
    };

    println!(
        "checkpoint i/o: sessions up to n={max_fleet} (s = {}, window {WINDOW}), dir {}",
        NX * NX + NX + 1,
        dir.display()
    );

    let fleet: Vec<Session> = (0..max_fleet as u64)
        .map(|id| build_session(id, &engine, &samples))
        .collect();
    let archive_bytes_per_session =
        encode_session(&fleet[0].snapshot()).len() as f64;

    let mut json_rows: Vec<String> = Vec::new();
    for &n in fleet_sizes {
        let encode = b
            .bench(&format!("encode_n{n}"), || {
                fleet[..n]
                    .iter()
                    .map(|s| encode_session(&s.snapshot()).len())
                    .sum::<usize>()
            })
            .median;

        let mut writer = ShardCheckpointer::new(&ckpt_cfg, 0);
        let snapshot = b
            .bench(&format!("snapshot_n{n}"), || {
                writer
                    .write_now(fleet[..n].iter())
                    .expect("bench checkpoint write");
            })
            .median;

        let cfg = session_config();
        let restore = b
            .bench(&format!("restore_n{n}"), || {
                let (snaps, corrupt) = load_all(&dir);
                assert_eq!(corrupt, 0);
                assert_eq!(snaps.len(), n);
                let restored: Vec<Session> = snaps
                    .into_iter()
                    .map(|snap| Session::restore(snap, cfg.clone()).expect("bench restore"))
                    .collect();
                restored.len()
            })
            .median;

        println!(
            "n {n:>3}: encode {encode:.3e} s  snapshot {snapshot:.3e} s  restore {restore:.3e} s"
        );
        let mut row = String::new();
        let _ = write!(
            row,
            "    {{\"sessions\": {n}, \"encode_median_s\": {encode:.6e}, \
             \"snapshot_median_s\": {snapshot:.6e}, \"restore_median_s\": {restore:.6e}}}"
        );
        json_rows.push(row);
    }

    // the alternative to rehydration: retrain the session from its raw
    // buffer (what a respawned shard would have to do without durable
    // checkpoints) — the contract is restore ≥ 10× cheaper per session
    let train_one = b
        .bench("train_one", || {
            build_session(0, &engine, &samples[..COLLECT])
        })
        .median;
    println!("train_one (retrain instead of restore): {train_one:.3e} s");

    b.write_csv("checkpoint_io.csv").expect("write csv");
    let rows = json_rows.join(",\n");
    let json = format!(
        "{{\n  \"scale\": {{\"s\": {}, \"n_c\": {N_C}, \"window\": {WINDOW}, \
         \"record_bytes_per_session\": {archive_bytes_per_session:.0}, \"smoke\": {smoke}}},\n  \
         \"fleets\": [\n{rows}\n  ],\n  \
         \"train_one_median_s\": {train_one:.6e}\n}}\n",
        NX * NX + NX + 1
    );
    write_results_file("BENCH_checkpoint.json", &json).expect("write BENCH_checkpoint.json");
    println!(
        "→ results/BENCH_checkpoint.json (copy to repo root to refresh the committed snapshot)"
    );
    let _ = fs::remove_dir_all(&dir);
}
