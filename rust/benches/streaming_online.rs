//! Streaming online ridge: incremental per-sample retrain (rank-1
//! Cholesky update/downdate + in-place re-solve, `linalg::OnlineRidge`)
//! vs the from-scratch batch retrain (re-accumulate the window's packed
//! Gram + full `cholesky_1d` solve) across window sizes — the cost the
//! Serve-phase drift adaptation used to pay per `retrain_after` batch.
//!
//! Writes `results/BENCH_streaming.json` with the per-window medians
//! and speedups (the repo-root `BENCH_streaming.json` is the committed
//! snapshot). The acceptance bar is incremental ≥ 10× from-scratch at
//! window N = 1024; the operation-count ratio predicts ~50× at paper
//! scale (N·s²/2 + s³/6 vs (2 + N_y)·s²), so the measured margin is
//! wide. Set `DFR_BENCH_SMOKE=1` for a few-iteration CI run at reduced
//! scale.

use std::fmt::Write as _;

use dfr_edge::linalg::ridge::{
    OnlineRidge, OnlineRidgeConfig, RidgeAccumulator, RidgeMethod,
};
use dfr_edge::util::bench::{bb, write_results_file, Bencher};
use dfr_edge::util::prng::Pcg32;

fn main() {
    let smoke = std::env::var("DFR_BENCH_SMOKE").as_deref() == Ok("1");
    // s = Nx² + Nx + 1: paper scale Nx = 30 → 931; smoke uses a small
    // odd s so the remainder lanes still run
    let (s, ny, windows, target): (usize, usize, &[usize], f64) = if smoke {
        (191, 5, &[32, 64], 0.02)
    } else {
        (931, 9, &[128, 256, 1024], 0.5)
    };
    let beta = 0.5f32;
    let mut rng = Pcg32::seed(0x051AE);
    let mut b = Bencher::with_target_time(target);

    let max_n = *windows.iter().max().unwrap();
    // one flat pool reused by every window size: n + spare samples for
    // the incremental stream to slide over
    let pool_len = max_n + 64;
    let flat: Vec<f32> = (0..pool_len * s).map(|_| rng.normal()).collect();
    let labels: Vec<usize> = (0..pool_len).map(|i| i % ny).collect();
    let sample = |i: usize| &flat[i * s..(i + 1) * s];

    let mut json_rows: Vec<String> = Vec::new();
    for &n in windows {
        // --- incremental: window accumulator pre-filled to steady state,
        // then one labelled sample per iteration (evict-downdate + update
        // + in-place re-solve; the default refactor cadence stays on so
        // the drift bound's amortized cost is part of the measurement)
        let mut online = OnlineRidge::new(
            s,
            ny,
            OnlineRidgeConfig {
                beta,
                lambda: 1.0,
                window: Some(n),
                refactor_every: 64,
            },
        );
        for i in 0..n {
            online.fold(sample(i), labels[i]);
        }
        online.solve_now();
        let mut next = n;
        let inc = b
            .bench(&format!("incremental_observe_w{n}_s{s}"), || {
                let i = next % pool_len;
                online.observe(sample(i), labels[i]);
                next += 1;
            })
            .median;

        // --- from-scratch: what a Serve-phase batch retrain pays for the
        // ridge system alone — re-stream the window through the blocked
        // Gram accumulator and run the full 1-D Cholesky solve at ONE β
        // (the real retrain sweeps four, so this understates the gap)
        let scratch = b
            .bench(&format!("from_scratch_retrain_w{n}_s{s}"), || {
                let mut acc = RidgeAccumulator::new(s, ny);
                for (chunk, lab) in flat[..n * s].chunks(32 * s).zip(labels[..n].chunks(32)) {
                    acc.accumulate_block(chunk, lab);
                }
                bb(acc.solve(beta, RidgeMethod::Cholesky1d))
            })
            .median;

        let speedup = scratch / inc;
        println!(
            "window {n:>5}: incremental {inc:.3e} s vs from-scratch {scratch:.3e} s  → {speedup:.1}×"
        );
        let mut row = String::new();
        let _ = write!(
            row,
            "    {{\"window\": {n}, \"incremental_median_s\": {inc:.6e}, \
             \"from_scratch_median_s\": {scratch:.6e}, \"speedup\": {speedup:.3}}}"
        );
        json_rows.push(row);
    }

    // --- λ-forgetting flavour (no eviction; √λ factor scaling instead)
    let mut forget = OnlineRidge::new(
        s,
        ny,
        OnlineRidgeConfig {
            beta,
            lambda: 0.99,
            window: None,
            refactor_every: 64,
        },
    );
    for i in 0..64 {
        forget.fold(sample(i), labels[i]);
    }
    forget.solve_now();
    let mut next = 64usize;
    let lam = b
        .bench(&format!("forgetting_observe_s{s}"), || {
            let i = next % pool_len;
            forget.observe(sample(i), labels[i]);
            next += 1;
        })
        .median;

    b.write_csv("streaming_online.csv").expect("write csv");
    let rows = json_rows.join(",\n");
    let json = format!(
        "{{\n  \"scale\": {{\"s\": {s}, \"ny\": {ny}, \"beta\": {beta}, \"smoke\": {smoke}}},\n  \
         \"windows\": [\n{rows}\n  ],\n  \
         \"forgetting_observe_median_s\": {lam:.6e}\n}}\n"
    );
    write_results_file("BENCH_streaming.json", &json).expect("write BENCH_streaming.json");
    println!(
        "→ results/BENCH_streaming.json (copy to repo root to refresh the committed snapshot)"
    );
}
