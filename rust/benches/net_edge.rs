//! Network edge bench — what the TCP front and session hibernation cost
//! (DESIGN.md §16).
//!
//! Three stages over a loopback [`NetServer`]:
//!
//!   * `wire_rtt`        — one framed Infer round-trip on a hot session:
//!     codec + syscalls + shard queue on an idle server (the latency
//!     floor every remote client pays);
//!   * `sustained_hot`   — several client threads hammering resident
//!     sessions: sustained req/s and exact client-side p99 (measures the
//!     edge + coordinator under concurrency, no hibernation);
//!   * `hibernate_churn` — many registered sessions over a small
//!     resident cap, touched at random so nearly every request pays a
//!     rehydrate + an eviction's bucket rewrite: sustained req/s and
//!     p99 of the worst-case cold path.
//!
//! Full run registers 10 000 sessions over a 256-session cap;
//! `DFR_BENCH_SMOKE=1` shrinks that to 200 over 32 for CI. Writes
//! `results/BENCH_net.json` (the repo-root copy is the committed
//! snapshot).

use std::fmt::Write as _;
use std::fs;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use dfr_edge::coordinator::engine::NativeEngine;
use dfr_edge::coordinator::{
    Client, HibernateConfig, NetConfig, NetServer, Request, Response, Server, ServerConfig,
    SessionConfig,
};
use dfr_edge::data::dataset::{Dataset, Sample};
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::util::bench::{write_results_file, Bencher};
use dfr_edge::util::prng::Pcg32;

const MINI: Profile = Profile {
    name: "mini",
    n_v: 2,
    n_c: 2,
    train: 20,
    test: 10,
    t_min: 10,
    t_max: 12,
};

const CLIENTS: usize = 4;
/// Churn sessions start here so they never collide with the hot set.
const CHURN_BASE: u64 = 1_000;

fn mini_session_config(collect: usize) -> SessionConfig {
    let mut scfg = SessionConfig::new(2, 2, collect);
    scfg.train.nx = 8;
    scfg.train.epochs = 3;
    scfg.train.res_decay_epochs = vec![2];
    scfg.train.out_decay_epochs = vec![2];
    scfg
}

fn p99(mut v: Vec<f64>) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(f64::total_cmp);
    v[((v.len() - 1) as f64 * 0.99) as usize]
}

/// Drive `client` against `make_req` until the deadline; returns
/// (request count, per-request latencies).
fn hammer(
    client: &mut Client,
    dur: Duration,
    mut make_req: impl FnMut() -> Request,
) -> (u64, Vec<f64>) {
    let mut lat = Vec::new();
    let mut n = 0u64;
    let until = Instant::now() + dur;
    while Instant::now() < until {
        let req = make_req();
        let t0 = Instant::now();
        let resp = client.call(&req).expect("bench request");
        lat.push(t0.elapsed().as_secs_f64());
        n += 1;
        assert!(
            !matches!(resp, Response::Rejected(_) | Response::Error { .. }),
            "bench request failed: {resp:?}"
        );
    }
    (n, lat)
}

fn main() {
    let smoke = std::env::var("DFR_BENCH_SMOKE").as_deref() == Ok("1");
    let (registered, resident, buckets, dur) = if smoke {
        (200u64, 32usize, 64usize, Duration::from_millis(300))
    } else {
        (10_000u64, 256usize, 256usize, Duration::from_secs(3))
    };
    let ds: Dataset = synth::generate_with(
        &MINI,
        synth::SynthConfig {
            noise: 0.3,
            freq_sep: 0.2,
            ar: 0.3,
        },
        0xBE7,
    );
    let dir = PathBuf::from(format!(
        "{}/dfr-bench-net-{}",
        std::env::temp_dir().display(),
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    let mut hib = HibernateConfig::new(&dir);
    hib.max_resident = resident;
    hib.buckets = buckets;
    let mut cfg = ServerConfig {
        queue_cap: 256,
        seed: 0xFEED,
        shards: 1,
        max_batch: 8,
        ..ServerConfig::new(mini_session_config(ds.train.len()))
    };
    cfg.hibernate = Some(hib);
    let srv = Arc::new(Server::spawn(Box::new(NativeEngine::new(8, 2)), cfg));
    let net = NetServer::bind(Arc::clone(&srv), NetConfig::default()).expect("bind loopback");
    let addr = net.local_addr();
    println!(
        "net edge on {addr}: {registered} registered sessions, cap {resident}, \
         {buckets} store buckets, {CLIENTS} clients, dir {}",
        dir.display()
    );

    // hot set: train sessions 0..CLIENTS to Serve over the wire
    let mut client = Client::connect(addr).expect("connect");
    for hot in 0..CLIENTS as u64 {
        for s in &ds.train {
            client
                .call(&Request::Labelled {
                    session: hot,
                    sample: s.clone(),
                })
                .expect("train hot session");
        }
    }

    // ---- wire_rtt -------------------------------------------------------
    let mut b = Bencher::with_target_time(if smoke { 0.02 } else { 0.2 });
    let probe = ds.test[0].clone();
    let rtt = b
        .bench("wire_rtt", || {
            client
                .call(&Request::Infer {
                    session: 0,
                    sample: probe.clone(),
                })
                .expect("rtt infer")
        })
        .median;
    println!("wire_rtt: {rtt:.3e} s");

    // ---- sustained_hot --------------------------------------------------
    let wall = Instant::now();
    let per_thread: Vec<(u64, Vec<f64>)> = thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS as u64)
            .map(|hot| {
                let ds = &ds;
                scope.spawn(move || {
                    let mut c = Client::connect(addr).expect("connect hot client");
                    let mut i = 0usize;
                    hammer(&mut c, dur, move || {
                        i += 1;
                        Request::Infer {
                            session: hot,
                            sample: ds.test[i % ds.test.len()].clone(),
                        }
                    })
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("hot client")).collect()
    });
    let hot_wall = wall.elapsed().as_secs_f64();
    let hot_n: u64 = per_thread.iter().map(|(n, _)| n).sum();
    let hot_lat: Vec<f64> = per_thread.into_iter().flat_map(|(_, l)| l).collect();
    let hot_rps = hot_n as f64 / hot_wall;
    let hot_p99 = p99(hot_lat);
    println!("sustained_hot: {hot_rps:.0} req/s  p99 {hot_p99:.3e} s  ({hot_n} reqs)");

    // ---- hibernate_churn ------------------------------------------------
    // register the fleet: one Collect-phase sample per session (cheap,
    // small snapshots); past the cap this already churns the store
    let reg0 = Instant::now();
    for id in 0..registered {
        srv.call(Request::Labelled {
            session: CHURN_BASE + id,
            sample: ds.train[0].clone(),
        })
        .expect("register session");
    }
    println!(
        "registered {registered} sessions in {:.2} s",
        reg0.elapsed().as_secs_f64()
    );
    // random touches over the whole fleet: with registered >> resident,
    // almost every request is a rehydrate + an eviction's bucket rewrite
    let mut rng = Pcg32::seed(0x0E6E);
    let wall = Instant::now();
    let (churn_n, churn_lat) = hammer(&mut client, dur, move || Request::Labelled {
        session: CHURN_BASE + u64::from(rng.next_u32()) % registered,
        sample: ds.train[1].clone(),
    });
    let churn_wall = wall.elapsed().as_secs_f64();
    let churn_rps = churn_n as f64 / churn_wall;
    let churn_p99 = p99(churn_lat);
    println!("hibernate_churn: {churn_rps:.0} req/s  p99 {churn_p99:.3e} s  ({churn_n} reqs)");

    b.write_csv("net_edge.csv").expect("write csv");
    let mut json = String::new();
    let _ = write!(
        json,
        "{{\n  \"scale\": {{\"registered\": {registered}, \"max_resident\": {resident}, \
         \"buckets\": {buckets}, \"clients\": {CLIENTS}, \"smoke\": {smoke}}},\n  \
         \"wire_rtt_median_s\": {rtt:.6e},\n  \
         \"sustained_hot\": {{\"req_per_s\": {hot_rps:.1}, \"p99_s\": {hot_p99:.6e}}},\n  \
         \"hibernate_churn\": {{\"req_per_s\": {churn_rps:.1}, \"p99_s\": {churn_p99:.6e}}}\n}}\n"
    );
    write_results_file("BENCH_net.json", &json).expect("write BENCH_net.json");
    println!("→ results/BENCH_net.json (copy to repo root to refresh the committed snapshot)");

    drop(net);
    if let Ok(owned) = Arc::try_unwrap(srv) {
        owned.shutdown();
    }
    let _ = fs::remove_dir_all(&dir);
}
