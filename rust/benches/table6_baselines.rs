//! Table 6: classification accuracy vs other machine-learning methods.
//!
//! Measured here: the proposed DFR (bp), our from-scratch MLP and the
//! ESN/TWIESN-style baseline, on the synthetic stand-ins. The deep
//! comparators (FCN, ResNet, Encoder, MCDCNN, Time-CNN) are carried as
//! the published constants the paper itself quotes from [12].

mod common;

use dfr_edge::baselines::published::{TABLE6, TABLE6_METHODS};
use dfr_edge::baselines::{mlp, twiesn};
use dfr_edge::dfr::train::{train, TrainConfig};

fn main() {
    let datasets: &[&str] = if common::full_mode() {
        &["arab", "aus", "char", "cmu", "ecg", "jpvow", "kick", "lib", "net", "uwav", "waf", "walk"]
    } else {
        &["jpvow", "ecg", "waf", "lib"]
    };

    println!("# Table 6 — accuracy vs other ML methods (measured on synthetic stand-ins)\n");
    println!(
        "{:<8} {:>8} {:>8} {:>8}   paper row (MLP..TWIESN, prop.bp)",
        "dataset", "DFR bp", "MLP", "ESN"
    );
    let mut rows = Vec::new();
    for name in datasets {
        let ds = common::bench_dataset(name, 42);

        let model = train(&ds, &TrainConfig::default());
        let dfr_acc = model.test_accuracy(&ds);

        let mlp_acc = mlp::evaluate(
            &ds,
            &mlp::MlpConfig {
                epochs: if common::full_mode() { 30 } else { 12 },
                ..Default::default()
            },
        );
        let esn_acc = twiesn::evaluate(&ds, twiesn::EsnConfig::default());

        let paper = TABLE6.iter().find(|(n, _)| n == name).unwrap();
        println!(
            "{:<8} {:>8.3} {:>8.3} {:>8.3}   {:?}",
            name, dfr_acc, mlp_acc, esn_acc, paper.1
        );
        rows.push(vec![
            name.to_string(),
            format!("{dfr_acc:.4}"),
            format!("{mlp_acc:.4}"),
            format!("{esn_acc:.4}"),
            format!("{:.3}", paper.1[0]),
            format!("{:.3}", paper.1[6]),
            format!("{:.3}", paper.1[7]),
        ]);
    }
    common::write_csv(
        "table6_baselines.csv",
        "dataset,dfr_bp_acc,mlp_acc,esn_acc,paper_mlp,paper_twiesn,paper_bp",
        &rows,
    );
    println!("\npublished columns: {TABLE6_METHODS:?}");
}
