//! Table 7: storage reduction by truncated backpropagation.
//!
//! The formulas reproduce the paper's printed words **exactly** (see
//! `dfr::backprop::memory_words_*`, verified in unit tests); this bench
//! prints the full table and cross-checks with live measurements of the
//! history buffers on one sample.

mod common;

use dfr_edge::data::profiles::PROFILES;
use dfr_edge::dfr::backprop::{memory_words_naive, memory_words_truncated};
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::reservoir::{Nonlinearity, Reservoir};
use dfr_edge::util::prng::Pcg32;

fn main() {
    println!("# Table 7 — storage reduction by truncated backpropagation\n");
    println!(
        "{:<8} {:>9} {:>11} {:>10}",
        "dataset", "naive", "simplified", "reduction"
    );
    let nx = 30;
    let mut rows = Vec::new();
    for p in &PROFILES {
        let naive = memory_words_naive(p.t_max, nx, p.n_c);
        let simp = memory_words_truncated(nx, p.n_c);
        let red = 100.0 * (naive - simp) as f64 / naive as f64;
        println!("{:<8} {:>9} {:>11} {:>9.0}%", p.name, naive, simp, red);
        rows.push(vec![
            p.name.to_string(),
            naive.to_string(),
            simp.to_string(),
            format!("{red:.1}"),
        ]);
    }
    common::write_csv(
        "table7_truncation.csv",
        "dataset,naive_words,simplified_words,reduction_pct",
        &rows,
    );

    // live cross-check: the full-BPTT history buffer really holds T·Nx
    // state words while the streaming forward holds 2·Nx
    let mut rng = Pcg32::seed(1);
    let t = 200;
    let v = 4;
    let res = Reservoir {
        mask: Mask::random(nx, v, &mut rng),
        p: 0.2,
        q: 0.1,
        f: Nonlinearity::Linear { alpha: 1.0 },
    };
    let u: Vec<f32> = (0..t * v).map(|_| rng.normal()).collect();
    let hist = res.forward_history(&u, t);
    assert_eq!(hist.xs.len(), t * nx, "history stores T*Nx words");
    let fwd = res.forward(&u, t);
    let live = fwd.x_t.len() + fwd.x_tm1.len();
    assert_eq!(live, 2 * nx, "streaming stores 2*Nx state words");
    println!(
        "\nlive check: history {} words vs streaming {} words (T={t})",
        hist.xs.len(),
        live
    );
}
