//! Coordinator scaling bench — req/s and latency percentiles of the
//! sharded server, swept two ways:
//!
//!   1. shard count 1/2/4/8 at the server-default batch depth (the
//!      "measured, not asserted" scaling claim), and
//!   2. batch depth `max_batch` ∈ {1, 8, 64} on a single shard — the
//!      per-call baseline (`max_batch = 1`) against the batched shard
//!      drain. With 8 blocking clients at most 8 requests are ever
//!      queued per shard, so the 64 row measures "cap above offered
//!      concurrency" and should track the 8 row.
//!
//! Multi-threaded clients fan blocking `call`s into the shard queues:
//! 16 pre-trained sessions spread across shards, 8 client threads each
//! issuing inference requests round-robin over the sessions. Per-request
//! latency is recorded client-side into `util::metrics` histograms and
//! merged; throughput is total requests over wall time. The mean shard
//! drain depth (requests per drain cycle, warm-up included — warm-up
//! trains serially, so it dilutes the mean toward 1) is recovered from
//! the server's own `batch_size` histogram and `requests_total` counter.
//! Results land in `results/coordinator_throughput.{csv,md}`.
//!
//! `DFR_BENCH_FULL=1` quadruples the request count (EXPERIMENTS-grade
//! numbers); `DFR_BENCH_SMOKE=1` shrinks the sweep to a CI smoke run;
//! the default keeps the whole sweep under ~30 s.

mod common;

use std::thread;

use dfr_edge::coordinator::{NativeEngine, Request, Response, Server, ServerConfig, SessionConfig};
use dfr_edge::data::dataset::Sample;
use dfr_edge::util::bench::{markdown_table, write_results_file};
use dfr_edge::util::metrics::{Histogram, HistogramSnapshot};
use dfr_edge::util::prng::Pcg32;
use dfr_edge::util::timer::{fmt_secs, Stopwatch};

// workload shape: heavy enough per request (T=120 reservoir steps, s=601
// features) that compute, not channel traffic, dominates
const N_V: usize = 8;
const N_C: usize = 4;
const NX: usize = 24;
const T: usize = 120;
const SESSIONS: usize = 16;
const CLIENTS: usize = 8;
const TRAIN_PER_SESSION: usize = 24;

fn sample(rng: &mut Pcg32) -> Sample {
    Sample {
        u: (0..T * N_V).map(|_| rng.normal()).collect(),
        t: T,
        label: rng.below(N_C as u32) as usize,
    }
}

struct RunResult {
    shards_effective: usize,
    req_s: f64,
    p50_s: f64,
    p99_s: f64,
    mean_drain: f64,
    stats_text: String,
}

/// First whitespace-separated token after `prefix` on any stats line,
/// parsed as f64 (aggregate counter/histogram lines from
/// `metrics::render`).
fn stat_after(stats: &str, prefix: &str) -> Option<f64> {
    stats.lines().find_map(|l| {
        l.strip_prefix(prefix)
            .and_then(|rest| rest.split_whitespace().next())
            .and_then(|tok| tok.parse().ok())
    })
}

fn run_config(shards: usize, max_batch: usize, reqs_per_client: usize) -> RunResult {
    let mut scfg = SessionConfig::new(N_V, N_C, TRAIN_PER_SESSION);
    scfg.train.nx = NX;
    scfg.train.epochs = 2;
    scfg.train.res_decay_epochs = vec![1];
    scfg.train.out_decay_epochs = vec![1];
    // single β: warm-up trains 16 sessions per config — skip the sweep,
    // the bench measures serving, not β selection
    scfg.train.betas = vec![1e-2];
    let srv = Server::spawn(
        Box::new(NativeEngine::new(NX, N_C)),
        ServerConfig {
            queue_cap: 4096,
            seed: 7,
            shards,
            max_batch,
            ..ServerConfig::new(scfg)
        },
    );

    // warm-up: train every session (the last collected sample triggers
    // the full §4.1 pipeline)
    let mut rng = Pcg32::seed(42);
    let train_samples: Vec<Sample> = (0..TRAIN_PER_SESSION).map(|_| sample(&mut rng)).collect();
    for sid in 0..SESSIONS as u64 {
        let mut trained = false;
        for s in &train_samples {
            if let Response::Trained { .. } = srv
                .call(Request::Labelled {
                    session: sid,
                    sample: s.clone(),
                })
                .expect("server alive")
            {
                trained = true;
            }
        }
        assert!(trained, "session {sid} never trained");
    }

    // measurement: CLIENTS threads × reqs_per_client blocking inferences
    let sw = Stopwatch::start();
    let latencies = thread::scope(|scope| {
        let mut workers = Vec::new();
        for c in 0..CLIENTS {
            let srv = &srv;
            workers.push(scope.spawn(move || {
                let mut rng = Pcg32::seed(0xC11E57 + c as u64);
                let probes: Vec<Sample> = (0..32).map(|_| sample(&mut rng)).collect();
                let hist = Histogram::default();
                for i in 0..reqs_per_client {
                    let sid = ((c + i * CLIENTS) % SESSIONS) as u64;
                    let req_sw = Stopwatch::start();
                    let resp = srv
                        .call(Request::Infer {
                            session: sid,
                            sample: probes[i % probes.len()].clone(),
                        })
                        .expect("server alive");
                    hist.record_secs(req_sw.elapsed_secs());
                    assert!(matches!(resp, Response::Prediction { .. }), "{resp:?}");
                }
                hist.snapshot()
            }));
        }
        let mut merged = HistogramSnapshot::default();
        for w in workers {
            merged.merge(&w.join().expect("client thread"));
        }
        merged
    });
    let wall = sw.elapsed_secs();

    let stats_text = match srv.call(Request::Stats).expect("stats") {
        Response::StatsText(t) => t,
        other => panic!("{other:?}"),
    };
    let shards_effective = srv.shards();
    srv.shutdown();

    // exact mean drain depth: shard-handled requests per drain cycle
    let shard_reqs = stat_after(&stats_text, "counter requests_total ");
    let drain_cycles = stat_after(&stats_text, "hist batch_size count ");
    let mean_drain = match (shard_reqs, drain_cycles) {
        (Some(r), Some(c)) if c > 0.0 => r / c,
        _ => f64::NAN,
    };

    RunResult {
        shards_effective,
        req_s: (CLIENTS * reqs_per_client) as f64 / wall,
        p50_s: latencies.quantile_secs(0.5),
        p99_s: latencies.quantile_secs(0.99),
        mean_drain,
        stats_text,
    }
}

fn main() {
    let smoke = std::env::var("DFR_BENCH_SMOKE").as_deref() == Ok("1");
    let reqs_per_client = if common::full_mode() {
        6000
    } else if smoke {
        60
    } else {
        1500
    };
    println!(
        "coordinator throughput: {CLIENTS} clients × {reqs_per_client} req, \
         {SESSIONS} sessions, {} cores",
        common::threads()
    );

    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut last_stats = String::new();
    let mut push_row = |rows: &mut Vec<Vec<String>>, sweep: &str, shards: usize, max_batch: usize, r: &RunResult, base: f64| {
        println!(
            "{sweep:>6} shards {shards} max_batch {max_batch:>2} (effective {}): \
             {:>9.0} req/s  p50 {:>10}  p99 {:>10}  mean drain {:.2}  ({:.2}x vs base)",
            r.shards_effective,
            r.req_s,
            fmt_secs(r.p50_s),
            fmt_secs(r.p99_s),
            r.mean_drain,
            r.req_s / base
        );
        rows.push(vec![
            sweep.to_string(),
            shards.to_string(),
            max_batch.to_string(),
            r.shards_effective.to_string(),
            format!("{:.0}", r.req_s),
            format!("{:.6e}", r.p50_s),
            format!("{:.6e}", r.p99_s),
            format!("{:.2}", r.mean_drain),
            format!("{:.2}", r.req_s / base),
        ]);
    };

    // sweep 1 — shard scaling at the server-default batch depth
    let shard_sweep: &[usize] = if smoke { &[1] } else { &[1, 2, 4, 8] };
    let mut base_req_s = None;
    for &shards in shard_sweep {
        let r = run_config(shards, 8, reqs_per_client);
        let base = *base_req_s.get_or_insert(r.req_s);
        push_row(&mut rows, "shards", shards, 8, &r, base);
        last_stats = r.stats_text.clone();
    }

    // sweep 2 — batch depth on a single shard; max_batch = 1 is the
    // per-call baseline (every request features + scores on its own)
    let mut base_req_s = None;
    for &max_batch in &[1usize, 8, 64] {
        let r = run_config(1, max_batch, reqs_per_client);
        let base = *base_req_s.get_or_insert(r.req_s);
        push_row(&mut rows, "batch", 1, max_batch, &r, base);
        last_stats = r.stats_text.clone();
    }

    common::write_csv(
        "coordinator_throughput.csv",
        "sweep,shards,max_batch,shards_effective,req_s,p50_s,p99_s,mean_drain,speedup",
        &rows,
    );
    let md = markdown_table(
        &[
            "sweep",
            "shards",
            "max_batch",
            "effective",
            "req/s",
            "p50 (s)",
            "p99 (s)",
            "mean drain",
            "speedup",
        ],
        &rows,
    );
    write_results_file("coordinator_throughput.md", &md).expect("write results");
    println!("\nper-shard metrics of the last run (Request::Stats):\n{last_stats}");
}
