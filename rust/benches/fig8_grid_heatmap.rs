//! Fig. 8: the recursive-refinement failure mode on CHAR — subdividing
//! the best coarse cell (level 2) can lock onto a suboptimal basin when
//! the coarse grid misses the global optimum.

mod common;

use dfr_edge::dfr::grid;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::train::TrainConfig;
use dfr_edge::util::prng::Pcg32;

fn main() {
    let ds = common::bench_dataset("char", 42);
    let cfg = TrainConfig::default();
    let mask = Mask::random(cfg.nx, ds.n_v, &mut Pcg32::seed(cfg.seed));
    let coarse = if common::full_mode() { 5 } else { 3 };

    println!("# Fig. 8 — two-level recursive grid refinement (CHAR)\n");
    let (l1, l2) = grid::recursive_refine(&ds, &mask, &cfg, coarse, common::threads());

    let mut rows = Vec::new();
    for (level, res) in [(1, &l1), (2, &l2)] {
        println!("## level {level} ({}x{} points)", res.divs, res.divs);
        for pt in &res.points {
            println!(
                "  p={:<9.4} q={:<9.4} acc={:.3}",
                pt.p, pt.q, pt.accuracy
            );
            rows.push(vec![
                level.to_string(),
                format!("{:.6}", pt.p),
                format!("{:.6}", pt.q),
                format!("{:.4}", pt.accuracy),
            ]);
        }
        println!(
            "  best: p={:.4} q={:.4} acc={:.3} ({:.1}s)\n",
            res.best.p, res.best.q, res.best.accuracy, res.seconds
        );
    }

    // a full fine sweep shows what refinement may have missed
    let fine = grid::search(&ds, &mask, &cfg, coarse * 2, common::threads());
    println!(
        "full fine sweep ({0}x{0}): best acc {1:.3} at p={2:.4} q={3:.4}",
        coarse * 2,
        fine.best.accuracy,
        fine.best.p,
        fine.best.q
    );
    if fine.best.accuracy > l2.best.accuracy + 1e-9 {
        println!("→ refinement LOST {:.3} accuracy (the paper's Fig. 8 failure mode)",
            fine.best.accuracy - l2.best.accuracy);
    }
    rows.push(vec![
        "fine".into(),
        format!("{:.6}", fine.best.p),
        format!("{:.6}", fine.best.q),
        format!("{:.4}", fine.best.accuracy),
    ]);
    common::write_csv("fig8_grid_heatmap.csv", "level,p,q,accuracy", &rows);
}
