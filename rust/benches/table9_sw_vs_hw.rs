//! Table 9 (+ Table 10): full HW/SW comparison on the JPVOW workload —
//! the paper's headline edge-system result (1/13 time, 1/27 energy).
//!
//! HW comes from the co-design simulator (schedules + resources +
//! power); SW from the calibrated Cortex-A9 model. The measured Rust
//! pipeline on this host is also reported for context.

mod common;

use dfr_edge::data::profiles::Profile;
use dfr_edge::dfr::train::{train, TrainConfig};
use dfr_edge::fpga::design::{sw_report, DesignConfig, SystemModel};
use dfr_edge::fpga::schedule::ShapeParams;
use dfr_edge::report;

fn main() {
    let prof = Profile::by_name("jpvow").unwrap();
    let shape = ShapeParams::new(30, prof.n_v as u64, prof.n_c as u64, prof.t_max as u64);
    let (n_train, epochs, n_betas, n_test) =
        (prof.train as u64, 25u64, 1u64, prof.test as u64);

    println!("# Table 9 — SW-only vs HW-only (jpvow workload)\n");
    println!(
        "{}",
        report::table9_markdown(shape, n_train, epochs, n_betas, n_test)
    );

    let hw = SystemModel::new(shape, DesignConfig::Standard).report(n_train, epochs, n_betas, n_test);
    let sw = sw_report(&shape, n_train, epochs, n_betas, n_test);
    let rows = vec![vec![
        format!("{:.3}", sw.calc_s()),
        format!("{:.3}", hw.calc_s()),
        format!("{:.2}", sw.calc_s() / hw.calc_s()),
        format!("{:.3}", sw.energy_j),
        format!("{:.3}", hw.energy_j),
        format!("{:.2}", sw.energy_j / hw.energy_j),
        format!("{:.3}", hw.power_w),
        format!("{}", hw.resources.lut),
        format!("{}", hw.resources.dsp),
    ]];
    common::write_csv(
        "table9_sw_vs_hw.csv",
        "sw_calc_s,hw_calc_s,time_ratio,sw_energy_j,hw_energy_j,energy_ratio,hw_power_w,hw_lut,hw_dsp",
        &rows,
    );

    println!("## Table 10 — per-module resources\n");
    let model = SystemModel::new(shape, DesignConfig::Standard);
    println!("{:<18} {:>8} {:>8} {:>6}", "module", "LUT", "FF", "DSP");
    let mut mrows = Vec::new();
    for m in model.modules() {
        let r = m.resources();
        println!("{:<18} {:>8} {:>8} {:>6}", m.name, r.lut, r.ff, r.dsp);
        mrows.push(vec![
            m.name.to_string(),
            r.lut.to_string(),
            r.ff.to_string(),
            r.dsp.to_string(),
        ]);
    }
    common::write_csv("table10_modules.csv", "module,lut,ff,dsp", &mrows);
    println!("\n(paper Table 10: dfr_core 8764/11266/15, bp 12245/10125/57, ridge 7827/8228/20)");

    // measured Rust pipeline on this host for context (not the A9!)
    let ds = common::bench_dataset("jpvow", 42);
    let model = train(&ds, &TrainConfig::default());
    println!(
        "\ncontext: this host's Rust pipeline on the subsampled workload: bp {:.2}s + ridge {:.2}s, acc {:.3}",
        model.bp_seconds,
        model.ridge_seconds,
        model.test_accuracy(&ds)
    );
}
