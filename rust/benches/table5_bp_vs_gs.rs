//! Table 5: proposed backpropagation vs grid search — accuracy, time,
//! and the divisions grid search needs to match bp.
//!
//! Reproduced shape: bp reaches accuracy comparable to the best grid
//! point while grid-search time grows quadratically with the division
//! count (the paper's 0.3×–700× span). Bench mode subsamples datasets;
//! `DFR_BENCH_FULL=1` uses the full Table 4 sizes.

mod common;

use dfr_edge::baselines::published::TABLE5;
use dfr_edge::dfr::grid;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::train::{train, TrainConfig};
use dfr_edge::util::prng::Pcg32;

fn main() {
    let datasets: &[&str] = if common::full_mode() {
        &["arab", "aus", "char", "cmu", "ecg", "jpvow", "kick", "lib", "net", "uwav", "waf", "walk"]
    } else {
        &["jpvow", "ecg", "cmu", "lib", "waf", "walk", "kick"]
    };
    let max_divs = if common::full_mode() { 10 } else { 5 };

    let mut rows = Vec::new();
    println!("# Table 5 — bp vs grid search\n");
    println!(
        "{:<8} {:>7} {:>9} {:>5} {:>9} {:>9}  (paper: acc/divs)",
        "dataset", "bp acc", "bp time", "divs", "gs time", "gs/bp"
    );
    for name in datasets {
        let ds = common::bench_dataset(name, 42);
        let cfg = TrainConfig::default();

        // proposed: truncated-BP SGD + ridge
        let model = train(&ds, &cfg);
        let bp_acc = model.test_accuracy(&ds);
        let bp_time = model.bp_seconds + model.ridge_seconds;

        // baseline: grid search until it matches bp accuracy
        let mask = Mask::random(cfg.nx, ds.n_v, &mut Pcg32::seed(cfg.seed));
        let sweeps = grid::search_until_match(
            &ds,
            &mask,
            &cfg,
            bp_acc,
            max_divs,
            common::threads(),
        );
        let gs_time: f64 = sweeps.iter().map(|s| s.seconds).sum();
        let last = sweeps.last().unwrap();
        let paper = TABLE5.iter().find(|(n, ..)| n == name).unwrap();
        println!(
            "{:<8} {:>7.3} {:>8.2}s {:>5} {:>8.2}s {:>8.1}x  (paper {:.3}/{})",
            name,
            bp_acc,
            bp_time,
            last.divs,
            gs_time,
            gs_time / bp_time,
            paper.1,
            paper.3,
        );
        rows.push(vec![
            name.to_string(),
            format!("{bp_acc:.4}"),
            format!("{bp_time:.3}"),
            format!("{}", last.divs),
            format!("{:.4}", last.best.accuracy),
            format!("{gs_time:.3}"),
            format!("{:.2}", gs_time / bp_time),
            format!("{:.3}", paper.1),
            format!("{}", paper.3),
        ]);
    }
    common::write_csv(
        "table5_bp_vs_gs.csv",
        "dataset,bp_acc,bp_time_s,gs_divs,gs_acc,gs_time_s,gs_over_bp,paper_bp_acc,paper_gs_divs",
        &rows,
    );
}
