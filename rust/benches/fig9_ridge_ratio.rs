//! Fig. 9: measured runtime ratio of Gaussian elimination to 1-D
//! Cholesky over the (Nx, Ny) plane.
//!
//! Shape target: the proposed method wins consistently for Nx > 10, by
//! ≈7× when Ny < 10, with the advantage shrinking as Ny grows (the
//! substitutions are Ny-proportional while the decomposition is not).

mod common;

use dfr_edge::linalg::ridge::{RidgeAccumulator, RidgeMethod};
use dfr_edge::util::bench::Bencher;
use dfr_edge::util::prng::Pcg32;

fn accumulator(s: usize, ny: usize, rng: &mut Pcg32) -> RidgeAccumulator {
    let mut acc = RidgeAccumulator::new(s, ny);
    // enough rank + a solid diagonal for a well-posed solve
    for i in 0..(s + 5) {
        let r: Vec<f32> = (0..s).map(|_| rng.normal()).collect();
        acc.accumulate(&r, i % ny);
    }
    acc
}

fn main() {
    let nxs: &[usize] = if common::full_mode() {
        &[2, 6, 10, 14, 18, 22, 26, 30, 34, 38]
    } else {
        &[2, 6, 10, 14, 18, 22]
    };
    let nys: &[usize] = &[1, 2, 5, 10, 25, 50, 95];

    println!("# Fig. 9 — runtime ratio Gaussian / Cholesky\n");
    print!("{:>5}", "Nx\\Ny");
    for ny in nys {
        print!("{ny:>8}");
    }
    println!();

    let mut rows = Vec::new();
    let mut rng = Pcg32::seed(0xF19);
    for &nx in nxs {
        let s = nx * nx + nx + 1;
        print!("{nx:>5}");
        for &ny in nys {
            let acc = accumulator(s, ny, &mut rng);
            let mut b = Bencher::with_target_time(0.12);
            b.quiet = true;
            let tg = b
                .bench(&format!("gauss_nx{nx}_ny{ny}"), || {
                    acc.solve(0.5, RidgeMethod::Gaussian)
                })
                .median;
            let tc = b
                .bench(&format!("chol_nx{nx}_ny{ny}"), || {
                    acc.solve(0.5, RidgeMethod::Cholesky1d)
                })
                .median;
            let ratio = tg / tc;
            print!("{ratio:>8.2}");
            rows.push(vec![
                nx.to_string(),
                ny.to_string(),
                format!("{tg:.6e}"),
                format!("{tc:.6e}"),
                format!("{ratio:.3}"),
            ]);
        }
        println!();
    }
    common::write_csv(
        "fig9_ridge_ratio.csv",
        "nx,ny,gaussian_s,cholesky_s,ratio",
        &rows,
    );
    println!("\n(paper: ≈7x for Ny<10 at practical Nx; consistent wins for Nx>10)");
}
