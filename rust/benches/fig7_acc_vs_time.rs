//! Fig. 7: accuracy versus optimization time on LIB — the proposed bp
//! reaches its accuracy orders of magnitude before grid search, whose
//! cumulative cost grows quadratically with the division count.

mod common;

use dfr_edge::dfr::grid;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::train::{train, TrainConfig};
use dfr_edge::util::prng::Pcg32;

fn main() {
    let ds = common::bench_dataset("lib", 42);
    let cfg = TrainConfig::default();

    println!("# Fig. 7 — accuracy vs computation time (LIB)\n");
    let mut rows = Vec::new();

    // proposed bp: single point (the paper plots the final result)
    let model = train(&ds, &cfg);
    let bp_acc = model.test_accuracy(&ds);
    let bp_time = model.bp_seconds + model.ridge_seconds;
    println!("bp:  acc {bp_acc:.3} at {bp_time:.2}s");
    rows.push(vec![
        "bp".into(),
        "0".into(),
        format!("{bp_time:.4}"),
        format!("{bp_acc:.4}"),
    ]);

    // grid search: cumulative time/best accuracy per division count
    let mask = Mask::random(cfg.nx, ds.n_v, &mut Pcg32::seed(cfg.seed));
    let max_divs = if common::full_mode() { 12 } else { 6 };
    let mut cum = 0.0;
    let mut best = 0.0f64;
    for divs in 1..=max_divs {
        let r = grid::search(&ds, &mask, &cfg, divs, common::threads());
        cum += r.seconds;
        best = best.max(r.best.accuracy);
        println!("gs {divs:>2} divs: best acc {best:.3} at cumulative {cum:.2}s");
        rows.push(vec![
            "gs".into(),
            divs.to_string(),
            format!("{cum:.4}"),
            format!("{best:.4}"),
        ]);
    }
    common::write_csv(
        "fig7_acc_vs_time.csv",
        "method,divs,cumulative_time_s,best_accuracy",
        &rows,
    );
}
