"""Emit golden npz files for the Rust cross-language tests.

Inputs are deterministic closed-form arrays (no PRNG to keep in sync):

    u[k, v]    = sin(0.1 (k+1) (v+1)) + 0.05 cos(0.3 (k+1))
    mask[n, v] = +1 if (7n + 3v) % 2 == 0 else -1

so `rust/src/dfr/` regenerates the identical inputs and compares its
forward pass / DPRR / truncated gradients against the JAX reference
recorded here. Written by `make artifacts` into artifacts/golden/.
"""

import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model
from compile.kernels import ref


def inputs(t, v, nx):
    k = np.arange(1, t + 1)[:, None]
    vv = np.arange(1, v + 1)[None, :]
    u = np.sin(0.1 * k * vv) + 0.05 * np.cos(0.3 * k)
    n = np.arange(nx)[:, None]
    vm = np.arange(v)[None, :]
    mask = np.where((7 * n + 3 * vm) % 2 == 0, 1.0, -1.0)
    return u.astype(np.float32), mask.astype(np.float32)


def golden_case(t, v, nx, c, p, q, length):
    u, mask = inputs(t, v, nx)
    uj, maskj = jnp.asarray(u), jnp.asarray(mask)
    r_mat, x_t, x_tm1, j_t = model.forward(
        uj, jnp.int32(length), maskj, p, q, use_pallas=False
    )
    # deterministic output layer + one-hot target for the gradient check
    s1 = nx * (nx + 1)
    w = (0.01 * np.sin(0.05 * np.arange(c * s1))).reshape(c, s1).astype(np.float32)
    b = np.linspace(-0.1, 0.1, c).astype(np.float32)
    e = np.zeros(c, np.float32)
    e[1 % c] = 1.0
    loss, dp, dq, dw, db = model.truncated_grads(
        r_mat, x_t, x_tm1, j_t, jnp.asarray(e), p, q, jnp.asarray(w),
        jnp.asarray(b), jnp.int32(length),
    )
    return {
        "t": np.int32(t),
        "v": np.int32(v),
        "nx": np.int32(nx),
        "c": np.int32(c),
        "p": np.float32(p),
        "q": np.float32(q),
        "length": np.int32(length),
        "u": u,
        "mask": mask,
        "r_mat": np.asarray(r_mat),
        "x_t": np.asarray(x_t),
        "x_tm1": np.asarray(x_tm1),
        "j_t": np.asarray(j_t),
        "w": w,
        "b": b,
        "e": e,
        "loss": np.float32(loss),
        "dp": np.float32(dp),
        "dq": np.float32(dq),
        "dw": np.asarray(dw),
        "db": np.asarray(db),
    }


CASES = [
    ("small", dict(t=12, v=2, nx=5, c=3, p=0.2, q=0.15, length=12)),
    ("padded", dict(t=40, v=3, nx=8, c=4, p=0.3, q=-0.2, length=23)),
    ("paper_nx30", dict(t=29, v=12, nx=30, c=9, p=0.1, q=0.05, length=29)),
]


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "golden"
    )
    os.makedirs(out_dir, exist_ok=True)
    for name, kw in CASES:
        path = os.path.join(out_dir, f"{name}.npz")
        np.savez(path, **golden_case(**kw))
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
