"""Loop-level Python mirror of `rust/src/quant/` — the validation artifact
for the fixed-point DFR datapath.

The authoring container has no Rust toolchain, so the quantized forward
pass, the PWL-LUT nonlinearity, and the analytic error budget are
mirrored here integer-for-integer and checked against an f64 reference
on the golden-fixture configurations (closed-form inputs, identical to
python/tests/make_golden.py). The committed Rust test tolerances in
rust/tests/quant_equivalence.rs were chosen from this script's output.

Run: python3 python/tests/quant_mirror.py
"""

import math

import numpy as np


# ---------------------------------------------------------------------------
# fixed-point core (mirror of rust/src/quant/fixed.rs)
# ---------------------------------------------------------------------------

class QFormat:
    def __init__(self, bits, frac):
        assert 2 <= bits <= 24 and frac < bits
        self.bits = bits
        self.frac = frac
        self.max_raw = (1 << (bits - 1)) - 1
        self.min_raw = -(1 << (bits - 1))

    @property
    def lsb(self):
        return 2.0 ** -self.frac

    def name(self):
        return f"Q{self.bits - self.frac}.{self.frac}"


class QArith:
    """Nearest (half-up) rounding + saturation — HLS AP_RND/AP_SAT."""

    def __init__(self, fmt):
        self.fmt = fmt
        self.saturations = 0

    def clamp(self, x):
        f = self.fmt
        if x > f.max_raw:
            self.saturations += 1
            return f.max_raw
        if x < f.min_raw:
            self.saturations += 1
            return f.min_raw
        return x

    def rescale(self, wide, shift):
        # divide by 2^shift, round half up (add half then floor-shift)
        return self.clamp((wide + (1 << (shift - 1))) >> shift)

    def quantize(self, x):
        if math.isnan(x):
            return 0
        scaled = float(x) * (1 << self.fmt.frac)
        if math.isinf(scaled):
            return self.clamp(self.fmt.max_raw + 1 if scaled > 0 else self.fmt.min_raw - 1)
        return self.clamp(math.floor(scaled + 0.5))

    def dequantize(self, raw):
        return raw / (1 << self.fmt.frac)

    def add(self, a, b):
        return self.clamp(a + b)

    def mul(self, a, b):
        return self.rescale(a * b, self.fmt.frac)


# ---------------------------------------------------------------------------
# PWL LUT (mirror of rust/src/quant/lut.rs)
# ---------------------------------------------------------------------------

class PwlLut:
    def __init__(self, f, arith, log2_segments):
        fmt = arith.fmt
        assert log2_segments <= fmt.bits
        self.arith = arith
        self.seg_shift = fmt.bits - log2_segments
        self.lo_raw = fmt.min_raw
        segs = 1 << log2_segments
        self.table = []
        for i in range(segs + 1):
            node_raw = self.lo_raw + (i << self.seg_shift)
            self.table.append(arith.quantize(f(node_raw / (1 << fmt.frac))))
        # measured sup-error over the range (dense sampling)
        self.max_err = 0.0
        for i in range(segs):
            for j in range(8):
                raw = self.lo_raw + (i << self.seg_shift) + (j * (1 << self.seg_shift)) // 8
                x = raw / (1 << fmt.frac)
                self.max_err = max(self.max_err, abs(self.eval_value(raw) - f(x)))

    def eval(self, x_raw):
        off = x_raw - self.lo_raw  # >= 0 (format-clamped input)
        idx = off >> self.seg_shift
        segs = len(self.table) - 1
        if idx >= segs:
            idx = segs - 1
        rem = off - (idx << self.seg_shift)
        y0 = self.table[idx]
        y1 = self.table[idx + 1]
        y = y0 + (((y1 - y0) * rem + (1 << (self.seg_shift - 1))) >> self.seg_shift)
        return self.arith.clamp(y)

    def eval_value(self, x_raw):
        return self.arith.dequantize(self.eval(x_raw))


# ---------------------------------------------------------------------------
# quantized forward (mirror of rust/src/quant/reservoir.rs)
# ---------------------------------------------------------------------------

def quant_forward(u, t, v, nx, mask, p, q, arith, lut):
    """Returns r_tilde (dequantized floats) for the modular DFR with
    Linear{alpha=1} nonlinearity evaluated through the LUT."""
    fmt = arith.fmt
    p_raw = arith.quantize(p)
    q_raw = arith.quantize(q)
    x = [0] * nx
    x_prev = [0] * nx
    acc = [0] * (nx * (nx + 1))  # wide, scale 2^(2 frac)
    w = nx + 1
    for k in range(t):
        x_prev[:] = x
        qu = [arith.quantize(u[k * v + vv]) for vv in range(v)]
        j = []
        for n in range(nx):
            s = 0
            for vv in range(v):
                s += qu[vv] if mask[n * v + vv] > 0 else -qu[vv]
            j.append(arith.clamp(s))
        prev_node = x[nx - 1]
        for n in range(nx):
            arg = arith.add(j[n], x[n])
            fx = lut.eval(arg)
            xn = arith.add(arith.mul(p_raw, fx), arith.mul(q_raw, prev_node))
            prev_node = xn
            x[n] = xn
        for i in range(nx):
            for jj in range(nx):
                acc[i * w + jj] += x[i] * x_prev[jj]
            acc[i * w + nx] += x[i] << fmt.frac
    # r = acc * (1/T); reciprocal held at 2*frac fractional bits
    inv_t_raw = ((1 << (2 * fmt.frac)) + t // 2) // t
    r = [arith.rescale(a * inv_t_raw, 3 * fmt.frac) for a in acc]
    r_tilde = [arith.dequantize(x) for x in r] + [1.0]
    return r_tilde, max(abs(xx) / (1 << fmt.frac) for xx in x)


def f64_forward(u, t, v, nx, mask, p, q):
    x = np.zeros(nx)
    x_prev = np.zeros(nx)
    acc = np.zeros(nx * (nx + 1))
    w = nx + 1
    x_abs_max = 0.0
    for k in range(t):
        x_prev[:] = x
        j = [sum(mask[n * v + vv] * u[k * v + vv] for vv in range(v)) for n in range(nx)]
        prev_node = x[nx - 1]
        for n in range(nx):
            xn = p * (j[n] + x[n]) + q * prev_node
            prev_node = xn
            x[n] = xn
        x_abs_max = max(x_abs_max, np.max(np.abs(x)))
        for i in range(nx):
            for jj in range(nx):
                acc[i * w + jj] += x[i] * x_prev[jj]
            acc[i * w + nx] += x[i]
    r = acc / t
    return list(r) + [1.0], x_abs_max


# ---------------------------------------------------------------------------
# analytic error budget (mirror of rust/src/quant/budget.rs)
# ---------------------------------------------------------------------------

def r_tilde_error_bound(fmt, p, q, lf, eps_f, t, nx, v, x_max, u_max, f_max):
    """Worst-case first-order error propagation through the quantized
    forward pass; see rust/src/quant/budget.rs for the derivation."""
    lsb = fmt.lsb
    half = lsb / 2.0
    ap, aq = abs(p), abs(q)
    # range check: saturation voids the linear error model
    j_max = v * u_max
    if max(x_max, j_max, j_max + x_max, f_max) * 1.05 > fmt.max_raw / (1 << fmt.frac):
        return float("inf")
    if ap * lf + aq >= 1.0:
        return float("inf")
    e_j = v * half
    e_state = 0.0
    for _ in range(t):
        e_prev_node = e_state
        worst = 0.0
        for _ in range(nx):
            e_n = (
                ap * lf * (e_j + e_state)
                + ap * eps_f
                + (f_max + x_max) * half  # p/q quantization error
                + lsb  # two product rescales, half-LSB each
                + aq * e_prev_node
            )
            e_prev_node = e_n
            worst = max(worst, e_n)
        e_state = worst
        if e_state > 1e6:
            return float("inf")
    inv_t_term = x_max * x_max * t * (2.0 ** -(2 * fmt.frac)) / 2.0
    return 2.0 * x_max * e_state + e_state * e_state + inv_t_term + half


# ---------------------------------------------------------------------------
# the golden-fixture configurations (make_golden.py CASES)
# ---------------------------------------------------------------------------

def closed_form_inputs(t, v, nx):
    k = np.arange(1, t + 1)[:, None]
    vv = np.arange(1, v + 1)[None, :]
    u = np.sin(0.1 * k * vv) + 0.05 * np.cos(0.3 * k)
    n = np.arange(nx)[:, None]
    vm = np.arange(v)[None, :]
    mask = np.where((7 * n + 3 * vm) % 2 == 0, 1.0, -1.0)
    return u.astype(np.float64).ravel(), mask.astype(np.float64).ravel()


CASES = [
    ("small", dict(t=12, v=2, nx=5, p=0.2, q=0.15)),
    ("padded", dict(t=23, v=3, nx=8, p=0.3, q=-0.2)),
    ("paper_nx30", dict(t=29, v=12, nx=30, p=0.1, q=0.05)),
]

FORMATS = [QFormat(16, 12), QFormat(16, 10), QFormat(16, 8)]


def random_property_cases(n_cases=200, seed=7):
    """Mirror of the rust property test's workload distribution
    (tests/quant_equivalence.rs::property_quant_forward_within_bound_…):
    p + |q| <= 0.6, |u| <= 1, v in 1..3, nx in 3..12, Q4.12."""
    rng = np.random.default_rng(seed)
    fmt = QFormat(16, 12)
    worst_margin = float("inf")
    for case in range(n_cases):
        nx = int(rng.integers(3, 13))
        v = int(rng.integers(1, 4))
        t = int(rng.integers(5, 35))
        p = 0.05 + 0.45 * rng.random()
        q = (0.6 - p) * rng.random() * (1 if rng.random() < 0.5 else -1)
        u = rng.uniform(-1, 1, t * v)
        mask = np.where(rng.random(nx * v) < 0.5, 1.0, -1.0)
        arith = QArith(fmt)
        lut = PwlLut(lambda x: x, arith, log2_segments=6)
        arith.saturations = 0  # discount LUT construction-time clamps
        got, _ = quant_forward(u, t, v, nx, mask, p, q, arith, lut)
        assert arith.saturations == 0, f"case {case}: saturated (p={p} q={q})"
        ref, x_max = f64_forward(u, t, v, nx, mask, p, q)
        dev = max(abs(a - b) for a, b in zip(got, ref))
        u_max = float(np.max(np.abs(u)))
        f_max = v * u_max + x_max
        bound = r_tilde_error_bound(fmt, p, q, 1.0, lut.max_err, t, nx, v, x_max, u_max, f_max)
        assert dev <= bound, f"case {case}: dev {dev} > bound {bound} (p={p} q={q} nx={nx} v={v} t={t})"
        if dev > 0:
            worst_margin = min(worst_margin, bound / dev)
    print(f"random property cases: {n_cases} OK, worst bound/dev margin {worst_margin:.1f}x")


def main():
    random_property_cases()
    ok = True
    for name, kw in CASES:
        t, v, nx, p, q = kw["t"], kw["v"], kw["nx"], kw["p"], kw["q"]
        u, mask = closed_form_inputs(t, v, nx)
        ref, x_max = f64_forward(u, t, v, nx, mask, p, q)
        u_max = float(np.max(np.abs(u)))
        j_max = v * u_max
        f_max = j_max + x_max  # Linear alpha=1
        for fmt in FORMATS:
            arith = QArith(fmt)
            lut = PwlLut(lambda x: x, arith, log2_segments=6)
            got, _ = quant_forward(u, t, v, nx, mask, p, q, arith, lut)
            dev = max(abs(a - b) for a, b in zip(got, ref))
            bound = r_tilde_error_bound(
                fmt, p, q, 1.0, lut.max_err, t, nx, v, x_max, u_max, f_max
            )
            status = "OK" if dev <= bound else "FAIL"
            if dev > bound:
                ok = False
            print(
                f"{name:<11} {fmt.name():>6}: dev {dev:.3e}  bound {bound:.3e}  "
                f"margin {bound / dev if dev > 0 else float('inf'):6.1f}x  "
                f"sat {arith.saturations:>3}  x_max {x_max:.3f} j_max {j_max:.2f}  {status}"
            )
    print("ALL OK" if ok else "BOUND VIOLATIONS FOUND")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
