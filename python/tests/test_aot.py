"""AOT path: HLO text emission, manifest contract, artifact freshness."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import aot  # noqa: E402
from compile.profiles import PROFILES  # noqa: E402


def test_hlo_text_emission_smallest_profile():
    prof = PROFILES["jpvow"]
    entries = aot.entry_points(prof)
    names = [e[0] for e in entries]
    assert names == ["forward", "train_step", "infer", "features", "step"]
    # lower the cheapest entry and check it is parseable HLO text
    name, fn, args, outs = entries[-1]
    lowered = jax.jit(fn).lower(*[a for _, a in args])
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_roundtrip(tmp_path):
    prof = PROFILES["jpvow"]
    m = aot.compile_profile(prof, str(tmp_path))
    assert m["s"] == 30 * 30 + 30 + 1 == 931
    assert set(m["entries"]) == {"forward", "train_step", "infer", "features", "step"}
    for e in m["entries"].values():
        assert os.path.exists(tmp_path / e["file"])
        assert all("dims" in a and "dtype" in a for a in e["args"])
    # incremental: second run must not rewrite
    mtimes = {e["file"]: os.path.getmtime(tmp_path / e["file"]) for e in m["entries"].values()}
    aot.compile_profile(prof, str(tmp_path))
    for f, t in mtimes.items():
        assert os.path.getmtime(tmp_path / f) == t


def test_profile_table_matches_paper_table4():
    """Table 4 constants."""
    expected = {
        "arab": (13, 10, 6600, 2200, 4, 93),
        "aus": (22, 95, 1140, 1425, 45, 136),
        "char": (3, 20, 300, 2558, 109, 205),
        "cmu": (62, 2, 29, 29, 127, 580),
        "ecg": (2, 2, 100, 100, 39, 152),
        "jpvow": (12, 9, 270, 370, 7, 29),
        "kick": (62, 2, 16, 10, 274, 841),
        "lib": (2, 15, 180, 180, 45, 45),
        "net": (4, 13, 803, 534, 50, 994),
        "uwav": (3, 8, 200, 427, 315, 315),
        "waf": (6, 2, 298, 896, 104, 198),
        "walk": (62, 2, 28, 16, 128, 1918),
    }
    assert set(PROFILES) == set(expected)
    for k, (v, c, tr, te, tmin, tmax) in expected.items():
        p = PROFILES[k]
        assert (p.n_v, p.n_c, p.train, p.test, p.t_min, p.t_max) == (
            v, c, tr, te, tmin, tmax,
        ), k


def test_repo_artifacts_manifest_if_present():
    """If `make artifacts` has run, the manifest must be consistent."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as fh:
        manifest = json.load(fh)
    for prof in manifest["profiles"].values():
        for e in prof["entries"].values():
            assert os.path.exists(os.path.join(root, e["file"])), e["file"]
