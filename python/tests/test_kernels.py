"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes/params; assert_allclose against ref — the core
correctness signal for everything the artifacts compute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile.kernels import dprr, ref, reservoir  # noqa: E402

F32 = jnp.float32


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, F32)


# ---------------------------------------------------------------------------
# reservoir step kernel
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    nx=st.integers(min_value=2, max_value=64),
    p=st.floats(min_value=-1.0, max_value=1.0, allow_nan=False),
    q=st.floats(min_value=-0.95, max_value=0.95, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_reservoir_step_matches_ref(nx, p, q, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x_prev = jax.random.normal(k1, (nx,), F32)
    j = jax.random.normal(k2, (nx,), F32)
    got = reservoir.reservoir_step(x_prev, j, p, q)
    want = ref.reservoir_step_ref(x_prev, j, p, q)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


@pytest.mark.parametrize("nx", [1, 2, 30])
def test_reservoir_step_zero_state_zero_input(nx):
    z = jnp.zeros((nx,), F32)
    got = reservoir.reservoir_step(z, z, 0.3, 0.4)
    np.testing.assert_allclose(got, np.zeros(nx), atol=0)


def test_reservoir_step_wrap_feedback():
    """x(k)_1 must see x(k-1)_{Nx} through q (Eq. 8 wrap)."""
    nx = 4
    x_prev = jnp.array([0.0, 0.0, 0.0, 2.0], F32)
    j = jnp.zeros((nx,), F32)
    q = 0.5
    got = np.asarray(reservoir.reservoir_step(x_prev, j, 0.0, q))
    # with p=0: x_1 = q * x_prev[Nx-1] = 1.0, x_n = q x_{n-1}
    np.testing.assert_allclose(got, [1.0, 0.5, 0.25, 0.125], rtol=1e-6)


def test_reservoir_step_negative_q():
    """Integer q-powers must handle q < 0 (reachable during SGD)."""
    nx = 8
    x_prev = rand(0, (nx,))
    j = rand(1, (nx,))
    got = reservoir.reservoir_step(x_prev, j, 0.5, -0.7)
    want = ref.reservoir_step_ref(x_prev, j, 0.5, -0.7)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)
    assert np.all(np.isfinite(np.asarray(got)))


def test_reservoir_step_mackey_glass_nl():
    nx = 16
    x_prev = rand(2, (nx,))
    j = rand(3, (nx,))
    f = lambda x: ref.f_mackey_glass(x, p_exp=2.0, eta=0.9)
    got = reservoir.reservoir_step(x_prev, j, 0.4, 0.2, f=f)
    want = ref.reservoir_step_ref(x_prev, j, 0.4, 0.2, f=f)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# DPRR kernel
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=300),
    nx=st.integers(min_value=2, max_value=40),
    block_t=st.sampled_from([8, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dprr_matches_ref(t, nx, block_t, seed):
    xs = jax.random.normal(jax.random.PRNGKey(seed), (t, nx), F32)
    got = dprr.dprr(xs, block_t=block_t)
    want = ref.dprr_ref(xs)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-3)


def test_dprr_single_step():
    """T=1: R = x(1) ⊗ [x(0)=0, 1] — only the sums column is nonzero."""
    xs = jnp.array([[1.0, 2.0, 3.0]], F32)
    r = np.asarray(dprr.dprr(xs))
    np.testing.assert_allclose(r[:, :3], np.zeros((3, 3)), atol=0)
    np.testing.assert_allclose(r[:, 3], [1.0, 2.0, 3.0], atol=0)


def test_dprr_block_t_invariance():
    xs = rand(7, (173, 13))
    a = dprr.dprr(xs, block_t=16)
    b = dprr.dprr(xs, block_t=173)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_dprr_pairs_equals_shifted():
    xs = rand(9, (50, 6))
    t, nx = xs.shape
    prev = jnp.concatenate([jnp.zeros((1, nx), F32), xs[:-1]], axis=0)
    hp = jnp.concatenate([prev, jnp.ones((t, 1), F32)], axis=1)
    np.testing.assert_allclose(
        dprr.dprr_pairs(xs, hp, block_t=32), ref.dprr_ref(xs), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# Mackey–Glass digital DFR reference (Eqs. 8-9) sanity
# ---------------------------------------------------------------------------


def test_mackey_glass_step_bounded():
    nx = 20
    x = jnp.zeros((nx,), F32)
    for k in range(50):
        j = rand(k, (nx,), scale=0.5)
        x = ref.mackey_glass_step_ref(x, j, gamma=0.5, eta=0.9, p_exp=2.0, theta=0.2)
    assert np.all(np.isfinite(np.asarray(x)))
    assert np.max(np.abs(np.asarray(x))) < 10.0


def test_hw_estimates_shapes():
    est = reservoir.reservoir_step_hw_estimate(30)
    assert est["vmem_bytes"] == (5 * 30 + 900) * 4
    est2 = dprr.dprr_hw_estimate(500, 30)
    assert est2["flops_total"] == 2 * 500 * 30 * 31
