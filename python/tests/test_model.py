"""L2 correctness: forward pass, padding gating, truncated-BP formulas,
training-protocol behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import sys, os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402

F32 = jnp.float32


def make_case(seed, t_pad=20, v=3, nx=8, c=4, scale_w=0.05):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    u = jax.random.normal(ks[0], (t_pad, v), F32)
    mask = jnp.where(jax.random.uniform(ks[1], (nx, v)) > 0.5, 1.0, -1.0).astype(F32)
    w = scale_w * jax.random.normal(ks[2], (c, nx * (nx + 1)), F32)
    b = jnp.zeros((c,), F32)
    return u, mask, w, b


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    length=st.integers(min_value=1, max_value=20),
)
def test_forward_pallas_matches_ref(seed, length):
    u, mask, _, _ = make_case(seed)
    got = model.forward(u, jnp.int32(length), mask, 0.2, 0.15, use_pallas=True)
    want = ref.forward_ref(u, length, mask, 0.2, 0.15)
    for g, w_, nm in zip(got, want, ["R", "xT", "xTm1", "jT"]):
        np.testing.assert_allclose(g, w_, rtol=1e-3, atol=1e-4, err_msg=nm)


def test_forward_padding_invariance():
    """Processing [u; garbage] with length=T equals processing u alone."""
    u, mask, _, _ = make_case(1, t_pad=15)
    garbage = 1e3 * jnp.ones((10, u.shape[1]), F32)
    u_padded = jnp.concatenate([u, garbage])
    a = model.forward(u, jnp.int32(15), mask, 0.3, 0.2, use_pallas=False)
    b = model.forward(u_padded, jnp.int32(15), mask, 0.3, 0.2, use_pallas=False)
    # states are bit-identical; R may differ by summation order only
    for x, y in zip(a[1:], b[1:]):
        np.testing.assert_allclose(x, y, atol=0)
    np.testing.assert_allclose(a[0], b[0], rtol=1e-5, atol=1e-5)


def test_forward_length_one():
    u, mask, _, _ = make_case(2)
    r_mat, x_t, x_tm1, j_t = model.forward(
        u, jnp.int32(1), mask, 0.5, 0.1, use_pallas=False
    )
    np.testing.assert_allclose(np.asarray(x_tm1), np.zeros_like(x_tm1), atol=0)
    # with x(0)=0 the pair block is zero, sums column equals x(1)
    np.testing.assert_allclose(
        np.asarray(r_mat[:, :-1]), np.zeros_like(r_mat[:, :-1]), atol=0
    )
    np.testing.assert_allclose(np.asarray(r_mat[:, -1]), np.asarray(x_t), atol=0)


# ---------------------------------------------------------------------------
# truncated backpropagation (Eqs. 33-36)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_truncated_grads_equal_surrogate_autodiff(seed):
    """The explicit formulas ARE the gradient of the truncated surrogate."""
    u, mask, w, b = make_case(seed)
    e = jax.nn.one_hot(seed % 4, 4)
    length = jnp.int32(12)
    p, q = 0.2, 0.15
    r_mat, x_t, x_tm1, j_t = model.forward(u, length, mask, p, q, use_pallas=False)
    _, dp, dq, dw, db = model.truncated_grads(r_mat, x_t, x_tm1, j_t, e, p, q, w, b, length)
    g = jax.grad(
        lambda pq: model.truncated_surrogate_loss(
            u, length, e, mask, pq[0], pq[1], w, b
        )
    )(jnp.array([p, q], F32))
    np.testing.assert_allclose(
        np.array([dp, dq]), np.asarray(g), rtol=1e-3, atol=1e-6
    )


def test_output_grads_equal_autodiff():
    """dW, db (Eq. 26) against autodiff of the full loss."""
    u, mask, w, b = make_case(3)
    e = jax.nn.one_hot(1, 4)
    length = jnp.int32(12)
    r_mat, x_t, x_tm1, j_t = model.forward(u, length, mask, 0.2, 0.15, use_pallas=False)
    _, _, _, dw, db = model.truncated_grads(r_mat, x_t, x_tm1, j_t, e, 0.2, 0.15, w, b, length)

    def loss_wb(wb):
        w_, b_ = wb
        y = model.output_layer(r_mat.reshape(-1), w_, b_)
        return model.cross_entropy(y, e)

    gw, gb = jax.grad(loss_wb)((w, b))
    np.testing.assert_allclose(dw, gw, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(db, gb, rtol=1e-4, atol=1e-6)


def test_truncated_grad_correlates_with_full_bptt():
    """The truncation (Eqs. 33-36) is an approximation of full BPTT
    (Eqs. 29-32); over a population of random cases its direction must
    agree with the true gradient in the majority of cases (the paper's
    §3.5 'diminishing impact of past states' argument). Deterministic
    seeds, so this is a fixed statistical fact, not a flaky test."""
    pos, total = 0, 20
    for seed in range(total):
        u, mask, w, b = make_case(seed)
        e = jax.nn.one_hot(seed % 4, 4)
        length = jnp.int32(18)
        p, q = 0.3, 0.2

        def full(pq):
            r_mat, *_ = model.forward(u, length, mask, pq[0], pq[1], use_pallas=False)
            y = model.output_layer(r_mat.reshape(-1), w, b)
            return model.cross_entropy(y, e)

        r_mat, x_t, x_tm1, j_t = model.forward(u, length, mask, p, q, use_pallas=False)
        _, dp, dq, _, _ = model.truncated_grads(r_mat, x_t, x_tm1, j_t, e, p, q, w, b, length)
        g_full = jax.grad(full)(jnp.array([p, q], F32))
        if float(dp * g_full[0] + dq * g_full[1]) > 0.0:
            pos += 1
    assert pos > total // 2, f"truncated grad agreed in only {pos}/{total} cases"


def test_train_step_reduces_loss_on_repeat():
    u, mask, w, b = make_case(5)
    e = jax.nn.one_hot(2, 4)
    length = jnp.int32(15)
    p, q = jnp.float32(0.01), jnp.float32(0.01)
    losses = []
    for _ in range(12):
        p, q, w, b, loss = model.train_step(
            u, length, e, mask, p, q, w, b, 0.05, 0.5, use_pallas=False
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


# ---------------------------------------------------------------------------
# inference / features
# ---------------------------------------------------------------------------


def test_infer_probabilities():
    u, mask, _, _ = make_case(6)
    c, s = 4, 8 * 9 + 1
    wt = 0.1 * jax.random.normal(jax.random.PRNGKey(9), (c, s), F32)
    y = model.infer(u, jnp.int32(10), mask, 0.2, 0.1, wt, use_pallas=False)
    y = np.asarray(y)
    assert y.shape == (c,)
    np.testing.assert_allclose(y.sum(), 1.0, rtol=1e-5)
    assert np.all(y >= 0)


def test_features_tilde_layout():
    u, mask, _, _ = make_case(7)
    rt = np.asarray(
        model.features(u, jnp.int32(10), mask, 0.2, 0.1, use_pallas=False)
    )
    assert rt.shape == (8 * 9 + 1,)
    assert rt[-1] == 1.0


def test_stream_step_matches_forward_chain():
    """Streaming path step-by-step equals the batch forward states."""
    u, mask, _, _ = make_case(8, t_pad=10)
    p, q = 0.25, 0.2
    x = jnp.zeros((8,), F32)
    for k in range(10):
        x = model.stream_step(x, u[k], mask, p, q, use_pallas=False)
    _, x_t, _, _ = model.forward(u, jnp.int32(10), mask, p, q, use_pallas=False)
    np.testing.assert_allclose(x, x_t, rtol=1e-5, atol=1e-6)
