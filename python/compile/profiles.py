"""Dataset profiles (paper Table 4) shared by the AOT compiler and tests.

Each profile fixes the static shapes an HLO artifact is specialized for:
input dimension V, class count C, padded series length T_pad (= T_max of
the dataset), and the reservoir size Nx (30 throughout the paper).

The Rust side carries the same table in `rust/src/data/profiles.rs`; the
`manifest.json` emitted by aot.py is the contract between the two.
"""

from dataclasses import dataclass


NX_DEFAULT = 30


@dataclass(frozen=True)
class Profile:
    name: str
    n_v: int  # input dimension  (#V)
    n_c: int  # output classes   (#C)
    train: int  # training samples
    test: int  # test samples
    t_min: int
    t_max: int
    nx: int = NX_DEFAULT

    @property
    def t_pad(self) -> int:
        return self.t_max

    @property
    def s(self) -> int:
        """Ridge system size s = Nx^2 + Nx + 1 (paper Eq. 20)."""
        return self.nx * self.nx + self.nx + 1


# Table 4 of the paper (#V, #C, Train, Test, Tmin, Tmax).
PROFILES = {
    "arab": Profile("arab", 13, 10, 6600, 2200, 4, 93),
    "aus": Profile("aus", 22, 95, 1140, 1425, 45, 136),
    "char": Profile("char", 3, 20, 300, 2558, 109, 205),
    "cmu": Profile("cmu", 62, 2, 29, 29, 127, 580),
    "ecg": Profile("ecg", 2, 2, 100, 100, 39, 152),
    "jpvow": Profile("jpvow", 12, 9, 270, 370, 7, 29),
    "kick": Profile("kick", 62, 2, 16, 10, 274, 841),
    "lib": Profile("lib", 2, 15, 180, 180, 45, 45),
    "net": Profile("net", 4, 13, 803, 534, 50, 994),
    "uwav": Profile("uwav", 3, 8, 200, 427, 315, 315),
    "waf": Profile("waf", 6, 2, 298, 896, 104, 198),
    "walk": Profile("walk", 62, 2, 28, 16, 128, 1918),
}

# Profiles compiled by default (`make artifacts`); jpvow is the paper's
# hardware-evaluation dataset (Table 9).
DEFAULT_PROFILES = ("jpvow", "ecg", "lib")
