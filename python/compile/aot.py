"""AOT compiler: lower the L2 model to HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per dataset profile (V, C, T_pad, Nx) five entry points are emitted:

  forward     (u[T,V], len, mask[Nx,V], p, q) -> (R, xT, xTm1, jT)
  train_step  (u, len, e[C], mask, p, q, W[C,s-1], b[C], lr_res, lr_out)
              -> (p', q', W', b', loss)
  infer       (u, len, mask, p, q, Wt[C,s]) -> y[C]
  features    (u, len, mask, p, q) -> r_tilde[s]
  step        (x_prev[Nx], u_t[V], mask, p, q) -> x[Nx]

plus `manifest.json` describing shapes and argument order — the contract
consumed by `rust/src/runtime/artifacts.rs`.

Usage:  python -m compile.aot --out-dir ../artifacts [--profiles jpvow,ecg]
        python -m compile.aot --all
Python runs only here (build time); the Rust binary is self-contained
afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .profiles import DEFAULT_PROFILES, PROFILES

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple so the Rust
    side always unwraps a tuple, even for single outputs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points(prof):
    """(name, python callable, arg specs, output names) per artifact."""
    t, v, c, nx = prof.t_pad, prof.n_v, prof.n_c, prof.nx
    s = prof.s
    u = spec((t, v))
    ln = spec((), I32)
    mask = spec((nx, v))
    sc = spec(())

    return [
        (
            "forward",
            lambda u, ln, m, p, q: model.forward(u, ln, m, p, q),
            [("u", u), ("length", ln), ("mask", mask), ("p", sc), ("q", sc)],
            ["r_mat", "x_t", "x_tm1", "j_t"],
        ),
        (
            "train_step",
            lambda u, ln, e, m, p, q, w, b, lr, lo: model.train_step(
                u, ln, e, m, p, q, w, b, lr, lo
            ),
            [
                ("u", u),
                ("length", ln),
                ("e", spec((c,))),
                ("mask", mask),
                ("p", sc),
                ("q", sc),
                ("w", spec((c, s - 1))),
                ("b", spec((c,))),
                ("lr_res", sc),
                ("lr_out", sc),
            ],
            ["p_new", "q_new", "w_new", "b_new", "loss"],
        ),
        (
            "infer",
            lambda u, ln, m, p, q, wt: (model.infer(u, ln, m, p, q, wt),),
            [
                ("u", u),
                ("length", ln),
                ("mask", mask),
                ("p", sc),
                ("q", sc),
                ("w_tilde", spec((c, s))),
            ],
            ["y"],
        ),
        (
            "features",
            lambda u, ln, m, p, q: (model.features(u, ln, m, p, q),),
            [("u", u), ("length", ln), ("mask", mask), ("p", sc), ("q", sc)],
            ["r_tilde"],
        ),
        (
            "step",
            lambda x, ut, m, p, q: (model.stream_step(x, ut, m, p, q),),
            [
                ("x_prev", spec((nx,))),
                ("u_t", spec((v,))),
                ("mask", mask),
                ("p", sc),
                ("q", sc),
            ],
            ["x"],
        ),
    ]


def _shape_of(sds):
    return {"dims": list(sds.shape), "dtype": str(sds.dtype)}


def compile_profile(prof, out_dir, force=False):
    entries = {}
    for name, fn, args, outs in entry_points(prof):
        fname = f"{name}_{prof.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        arg_specs = [a for _, a in args]
        key = hashlib.sha256(
            json.dumps(
                [name, prof.name, [(n, _shape_of(a)) for n, a in args]]
            ).encode()
        ).hexdigest()[:16]
        entries[name] = {
            "file": fname,
            "args": [{"name": n, **_shape_of(a)} for n, a in args],
            "outputs": outs,
            "key": key,
        }
        if not force and os.path.exists(path):
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"  wrote {fname} ({len(text)} chars)")
    return {
        "name": prof.name,
        "n_v": prof.n_v,
        "n_c": prof.n_c,
        "t_pad": prof.t_pad,
        "nx": prof.nx,
        "s": prof.s,
        "train": prof.train,
        "test": prof.test,
        "t_min": prof.t_min,
        "t_max": prof.t_max,
        "entries": entries,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--profiles",
        default=",".join(DEFAULT_PROFILES),
        help="comma-separated profile names (see profiles.py)",
    )
    ap.add_argument("--all", action="store_true", help="compile all 12 profiles")
    ap.add_argument("--force", action="store_true", help="recompile even if fresh")
    args = ap.parse_args()

    names = list(PROFILES) if args.all else args.profiles.split(",")
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"nx_default": 30, "profiles": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            try:
                manifest = json.load(fh)
            except json.JSONDecodeError:
                pass

    for n in names:
        prof = PROFILES[n.strip()]
        print(f"profile {prof.name}: V={prof.n_v} C={prof.n_c} T_pad={prof.t_pad}")
        manifest["profiles"][prof.name] = compile_profile(
            prof, args.out_dir, force=args.force
        )

    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"manifest: {manifest_path}")


if __name__ == "__main__":
    main()
