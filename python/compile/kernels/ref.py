"""Pure-jnp oracle for the Pallas kernels (L1 correctness reference).

Implements the modular DFR recurrences exactly as written in the paper:

  Eq. (14)   x(k)_n = p * f(j(k)_n + x(k-1)_n) + q * x(k)_{n-1}
             with the feedback-loop wrap x(k)_0 = x(k-1)_{Nx}
  Eqs. (27)  r_{(i-1)Nx+j} = sum_k x(k)_i * x(k-1)_j
  and (28)   r_{Nx^2+i}    = sum_k x(k)_i

as straightforward sequential loops — the gold standard the vectorized
Pallas kernels in `reservoir.py` / `dprr.py` are tested against.
"""

import jax
import jax.numpy as jnp


def f_linear(x, alpha=1.0):
    """The nonlinear function used throughout the paper's evaluation
    (Section 4: "f(x) = alpha * x ... as recommended in [11]")."""
    return alpha * x


def f_mackey_glass(x, p_exp=1.0, eta=1.0):
    """Mackey–Glass nonlinearity (paper Eq. (3)) for the conventional
    digital DFR baseline."""
    ax = jnp.abs(x)
    return eta * x / (1.0 + ax**p_exp)


def reservoir_step_ref(x_prev, j, p, q, f=f_linear):
    """One modular-DFR time step, sequential over virtual nodes.

    x_prev: [Nx] reservoir state x(k-1);  j: [Nx] masked input j(k).
    Returns x(k): [Nx].
    """
    nx = x_prev.shape[0]
    c = p * f(j + x_prev)  # per-node drive, Eq. (14) first term

    def body(carry, cn):
        xn = cn + q * carry
        return xn, xn

    # wrap: x(k)_0 == x(k-1)_{Nx}
    _, xs = jax.lax.scan(body, x_prev[nx - 1], c)
    return xs


def mackey_glass_step_ref(x_prev, j, gamma, eta, p_exp, theta):
    """One time step of the conventional digital DFR (paper Eqs. (8)-(9)).

    x(k)_1 = x(k-1)_{Nx} e^-theta + (1 - e^-theta) f(x(k-1)_1, j(k)_1)
    x(k)_n = x(k)_{n-1} e^-theta + (1 - e^-theta) f(x(k-1)_n, j(k)_n)
    with f the Mackey-Glass map of Eq. (3).
    """
    nx = x_prev.shape[0]
    e = jnp.exp(-theta)
    u = x_prev + gamma * j
    fv = eta * u / (1.0 + jnp.abs(u) ** p_exp)

    def body(carry, fn):
        xn = carry * e + (1.0 - e) * fn
        return xn, xn

    _, xs = jax.lax.scan(body, x_prev[nx - 1], fv)
    return xs


def dprr_ref(xs):
    """DPRR from the full state history, sequential over time.

    xs: [T, Nx] with xs[k] = x(k+1) (x(0) = 0 is implicit).
    Returns R: [Nx, Nx+1] where R[i, j<Nx] = sum_k x(k)_i x(k-1)_j and
    R[i, Nx] = sum_k x(k)_i  (Eqs. (27)-(28) laid out as a matrix;
    r = vec(R) row-major).
    """
    t, nx = xs.shape
    prev = jnp.concatenate([jnp.zeros((1, nx), xs.dtype), xs[:-1]], axis=0)
    prev_aug = jnp.concatenate([prev, jnp.ones((t, 1), xs.dtype)], axis=1)

    def body(acc, kv):
        xk, pk = kv
        return acc + jnp.outer(xk, pk), None

    acc0 = jnp.zeros((nx, nx + 1), xs.dtype)
    acc, _ = jax.lax.scan(body, acc0, (xs, prev_aug))
    return acc


def forward_ref(u, length, mask, p, q, f=f_linear):
    """Full forward pass oracle over a padded series.

    u: [T_pad, V], length: scalar int (valid prefix), mask: [Nx, V].
    Returns (R [Nx,Nx+1], x_T [Nx], x_Tm1 [Nx], j_T [Nx]).
    Padded steps (k >= length) leave all state untouched.
    """
    t_pad, _ = u.shape
    nx = mask.shape[0]
    dtype = u.dtype

    x = jnp.zeros((nx,), dtype)
    x_m1 = jnp.zeros((nx,), dtype)
    j_last = jnp.zeros((nx,), dtype)
    acc = jnp.zeros((nx, nx + 1), dtype)
    for k in range(t_pad):
        valid = k < length
        jk = mask @ u[k]
        x_new = reservoir_step_ref(x, jk, p, q, f)
        prev_aug = jnp.concatenate([x, jnp.ones((1,), dtype)])
        acc = jnp.where(valid, acc + jnp.outer(x_new, prev_aug), acc)
        x_m1 = jnp.where(valid, x, x_m1)
        j_last = jnp.where(valid, jk, j_last)
        x = jnp.where(valid, x_new, x)
    inv_t = 1.0 / jnp.maximum(jnp.asarray(length), 1).astype(dtype)
    return acc * inv_t, x, x_m1, j_last
