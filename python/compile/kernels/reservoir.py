"""L1 Pallas kernel: one modular-DFR time step over all Nx virtual nodes.

The paper's FPGA pipelines the node cascade

    x(k)_n = p * f(j(k)_n + x(k-1)_n) + q * x(k)_{n-1}     (Eq. 14)

at II=1 over n. That schedule is meaningless on a TPU; the hardware
adaptation (DESIGN.md §Hardware-Adaptation) re-expresses the first-order
linear recurrence in closed form as a dense lower-triangular matvec that
feeds the MXU:

    c_n     = p * f(j_n + x(k-1)_n)                (vectorised, VPU)
    x(k)_n  = q^n * x(k-1)_{Nx} + sum_{m<=n} q^{n-m} c_m
            = qpow_n * x0 + (L @ c)_n              (MXU, L[n,m] = q^{n-m})

The q-power matrix L is rebuilt from the traced scalar q each step; with
Nx = 30 it is a 30x30 fp32 tile, far below one MXU pass — the whole state
update lives in VMEM.

Kernel runs `interpret=True` so the CPU PJRT plugin can execute the
lowered HLO (real-TPU lowering emits a Mosaic custom-call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _powers_matrix(q, nx, dtype):
    """L[n, m] = q^(n-m) for m <= n else 0, plus the q^n wrap vector.

    Integer exponents so a negative q (reachable during SGD) stays exact.
    """
    n_idx = jax.lax.broadcasted_iota(jnp.int32, (nx, nx), 0)
    m_idx = jax.lax.broadcasted_iota(jnp.int32, (nx, nx), 1)
    diff = n_idx - m_idx
    tri = (diff >= 0).astype(dtype)
    # q^diff via exp/log is invalid for q<=0; use cumulative products:
    # row of powers [q^0, q^1, ..., q^(nx-1)] then gather by diff.
    pows = jnp.concatenate(
        [jnp.ones((1,), dtype), jnp.cumprod(jnp.full((nx - 1,), q, dtype))]
    )
    l_mat = tri * pows[jnp.clip(diff, 0, nx - 1)]
    # wrap coefficients q^n for n = 1..Nx
    qpow = pows * q
    return l_mat, qpow


def _step_kernel(xprev_ref, j_ref, pq_ref, x_ref, *, nx, f):
    """Pallas body: state update for one time step.

    xprev_ref: [1, Nx]   x(k-1)
    j_ref:     [1, Nx]   masked input j(k)
    pq_ref:    [1, 2]    packed (p, q) scalars
    x_ref:     [1, Nx]   out: x(k)
    """
    xprev = xprev_ref[0, :]
    j = j_ref[0, :]
    p = pq_ref[0, 0]
    q = pq_ref[0, 1]
    dtype = xprev.dtype

    c = p * f(j + xprev)
    l_mat, qpow = _powers_matrix(q, nx, dtype)
    x0 = xprev[nx - 1]
    x = qpow * x0 + l_mat @ c
    x_ref[0, :] = x


@functools.partial(jax.jit, static_argnames=("f",))
def reservoir_step(x_prev, j, p, q, f=ref.f_linear):
    """One modular-DFR time step via the Pallas kernel.

    x_prev: [Nx], j: [Nx], p/q scalars. Returns x(k): [Nx].
    Matches `ref.reservoir_step_ref` to fp32 round-off.
    """
    nx = x_prev.shape[0]
    dtype = x_prev.dtype
    pq = jnp.stack([jnp.asarray(p, dtype), jnp.asarray(q, dtype)]).reshape(1, 2)
    out = pl.pallas_call(
        functools.partial(_step_kernel, nx=nx, f=f),
        out_shape=jax.ShapeDtypeStruct((1, nx), dtype),
        interpret=True,
    )(x_prev.reshape(1, nx), j.reshape(1, nx), pq)
    return out[0]


def reservoir_step_hw_estimate(nx, dtype_bytes=4):
    """VMEM footprint / MXU-shape estimate for DESIGN.md §Perf (L1).

    Returns a dict with the VMEM working set (bytes) and the MXU tile
    occupancy of the triangular matvec, the quantities the paper budgets
    as BRAM/DSP on the Zynq.
    """
    vecs = 5 * nx  # xprev, j, c, qpow, x
    l_mat = nx * nx
    vmem_bytes = (vecs + l_mat) * dtype_bytes
    mxu = 128 * 128
    return {
        "vmem_bytes": vmem_bytes,
        "mxu_tile_utilization": (nx * nx) / mxu,
        "flops_per_step": 2 * nx * nx + 6 * nx,
    }
