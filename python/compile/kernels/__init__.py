"""L1 Pallas kernels for the DFR hot paths + pure-jnp oracle."""

from . import dprr, ref, reservoir  # noqa: F401
