"""L1 Pallas kernel: DPRR accumulation as a tiled matmul.

The paper computes the dot-product reservoir representation (Eqs. 27-28)
on the FPGA as T rank-1 sum-of-products updates with a BRAM write buffer
(Algorithm 5 / Fig. 10). On a TPU the same reduction is one matmul:

    X      = [x(1); ...; x(T)]            in R^{T x Nx}
    X'     = [[x(0),1]; ...; [x(T-1),1]]  in R^{T x (Nx+1)}
    R      = X^T @ X'                     in R^{Nx x (Nx+1)}

so r = vec(R) (row-major) reproduces r_{(i-1)Nx+j} = sum_k x(k)_i x(k-1)_j
and r_{Nx^2+i} = sum_k x(k)_i in one MXU-shaped contraction.

The kernel tiles the T (reduction) axis with BlockSpec so each grid step
streams one [bt, Nx] / [bt, Nx+1] pair HBM->VMEM and accumulates the
[Nx, Nx+1] output tile in place — the TPU analogue of the paper's write
buffer (the output tile never leaves VMEM during the reduction).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dprr_kernel(x_ref, xprev_ref, o_ref):
    """Grid step i accumulates chunk i of the T-reduction.

    x_ref:     [bt, Nx]    chunk of X
    xprev_ref: [bt, Nx+1]  chunk of X' (augmented with the ones column)
    o_ref:     [Nx, Nx+1]  accumulator tile (same block every grid step)
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    xp = xprev_ref[...]
    o_ref[...] += jnp.dot(
        x.T, xp, preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_t",))
def dprr(xs, block_t=128):
    """DPRR matrix R = X^T X' from the state history.

    xs: [T, Nx] with xs[k] = x(k+1); x(0) = 0 implicit.
    Returns R: [Nx, Nx+1]. Matches `ref.dprr_ref`.
    """
    t, nx = xs.shape
    dtype = xs.dtype
    prev = jnp.concatenate([jnp.zeros((1, nx), dtype), xs[:-1]], axis=0)
    prev_aug = jnp.concatenate([prev, jnp.ones((t, 1), dtype)], axis=1)

    bt = min(block_t, t)
    # pad T to a multiple of bt (zero rows contribute nothing)
    t_pad = ((t + bt - 1) // bt) * bt
    if t_pad != t:
        pad = ((0, t_pad - t), (0, 0))
        xs = jnp.pad(xs, pad)
        prev_aug = jnp.pad(prev_aug, pad)

    grid = (t_pad // bt,)
    return pl.pallas_call(
        _dprr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, nx), lambda i: (i, 0)),
            pl.BlockSpec((bt, nx + 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((nx, nx + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, nx + 1), dtype),
        interpret=True,
    )(xs, prev_aug)


@functools.partial(jax.jit, static_argnames=("block_t",))
def dprr_pairs(hx, hp, block_t=128):
    """R = hx^T @ hp for pre-shifted/pre-gated history pairs.

    hx: [T, Nx] rows x(k) (zeroed on padded steps), hp: [T, Nx+1] rows
    [x(k-1), 1] (zeroed likewise). Used by `model.forward`, which builds
    the pairs inside its scan so length-gating happens once.
    """
    t, nx = hx.shape
    dtype = hx.dtype
    bt = min(block_t, t)
    t_pad = ((t + bt - 1) // bt) * bt
    if t_pad != t:
        pad = ((0, t_pad - t), (0, 0))
        hx = jnp.pad(hx, pad)
        hp = jnp.pad(hp, pad)
    grid = (t_pad // bt,)
    return pl.pallas_call(
        _dprr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, nx), lambda i: (i, 0)),
            pl.BlockSpec((bt, nx + 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((nx, nx + 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, nx + 1), dtype),
        interpret=True,
    )(hx, hp)


def dprr_hw_estimate(t, nx, block_t=128, dtype_bytes=4):
    """VMEM/MXU estimate for DESIGN.md §Perf (L1).

    Working set per grid step: input chunk pair + resident accumulator.
    """
    bt = min(block_t, t)
    in_bytes = bt * (2 * nx + 1) * dtype_bytes
    acc_bytes = nx * (nx + 1) * dtype_bytes
    flops = 2 * t * nx * (nx + 1)
    return {
        "vmem_bytes_per_step": in_bytes + acc_bytes,
        "mxu_tile_utilization": min(1.0, (nx * (nx + 1)) / (128 * 128)),
        "flops_total": flops,
        "hbm_traffic_bytes": t * (2 * nx + 1) * dtype_bytes + acc_bytes,
    }
