"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernels.

Everything the FPGA datapath computes per sample lives here:

  forward       mask -> modular reservoir (scan over time, Pallas step
                kernel) -> DPRR (Pallas matmul kernel) -> (R, x_T, x_{T-1},
                j_T) — paper Eqs. (14), (27), (28)
  train_step    forward + softmax cross-entropy (Eqs. 24-25) + TRUNCATED
                backpropagation (Eqs. 26, 33-36) + SGD update — the
                paper's reservoir-parameter optimization contribution
  infer         forward + output layer y = W̃_out r̃ (Eq. 17)
  step          single streaming state update (online path)

These functions are lowered ONCE per dataset profile by `aot.py` to HLO
text; the Rust runtime executes them via PJRT. The in-place Cholesky ridge
regression (Algorithms 1-5) intentionally does NOT live here — it is the
paper's memory-layout contribution and is implemented natively in
`rust/src/linalg/` (see DESIGN.md §2).

Shapes are static per profile: u [T_pad, V] padded, `length` an int32
scalar selecting the valid prefix; padded steps are fully gated so results
are bit-identical to processing the unpadded series.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import dprr as dprr_k
from .kernels import ref
from .kernels import reservoir as res_k


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def forward(u, length, mask, p, q, f=ref.f_linear, use_pallas=True):
    """Reservoir forward pass over a padded series.

    u: [T_pad, V] float32, length: int32 scalar, mask: [Nx, V], p/q scalars.
    Returns (R [Nx, Nx+1], x_T [Nx], x_Tm1 [Nx], j_T [Nx]).
    """
    t_pad, _ = u.shape
    nx = mask.shape[0]
    dtype = u.dtype
    step_fn = res_k.reservoir_step if use_pallas else ref.reservoir_step_ref

    js = u @ mask.T  # [T_pad, Nx] masked inputs j(k) = M u(k)

    def body(carry, inp):
        x, x_m1, j_last = carry
        jk, k = inp
        valid = k < length
        x_new = step_fn(x, jk, p, q, f)
        # per-step DPRR rows, zeroed when padded (kills the contribution)
        hist_x = jnp.where(valid, x_new, jnp.zeros_like(x_new))
        hist_prev = jnp.where(
            valid,
            jnp.concatenate([x, jnp.ones((1,), dtype)]),
            jnp.zeros((nx + 1,), dtype),
        )
        x_m1 = jnp.where(valid, x, x_m1)
        j_last = jnp.where(valid, jk, j_last)
        x = jnp.where(valid, x_new, x)
        return (x, x_m1, j_last), (hist_x, hist_prev)

    zero = jnp.zeros((nx,), dtype)
    (x_t, x_tm1, j_t), (hx, hp) = jax.lax.scan(
        body, (zero, zero, zero), (js, jnp.arange(t_pad, dtype=jnp.int32))
    )
    if use_pallas:
        r_mat = dprr_k.dprr_pairs(hx, hp)
    else:
        r_mat = hx.T @ hp
    # 1/T normalization: keeps feature magnitude (and the fixed β grid)
    # independent of the series length — see rust/src/dfr/reservoir.rs
    # and DESIGN.md §10.
    inv_t = 1.0 / jnp.maximum(length, 1).astype(dtype)
    return r_mat * inv_t, x_t, x_tm1, j_t


# ---------------------------------------------------------------------------
# output layer + loss (Eqs. 13, 24, 25)
# ---------------------------------------------------------------------------


def output_layer(r, w, b):
    """y = softmax(W r + b). r: [s-1], w: [C, s-1], b: [C]."""
    z = w @ r + b
    z = z - jnp.max(z)
    ez = jnp.exp(z)
    return ez / jnp.sum(ez)


def cross_entropy(y, e, eps=1e-12):
    """Paper Eq. (24)."""
    return -jnp.sum(e * jnp.log(y + eps))


# ---------------------------------------------------------------------------
# truncated backpropagation (Eqs. 25-26, 33-36)
# ---------------------------------------------------------------------------


def truncated_grads(r_mat, x_t, x_tm1, j_t, e, p, q, w, b, t_len, f=ref.f_linear):
    """Explicit truncated-BP gradients, the paper's formulas verbatim
    (with the DPRR 1/T normalization carried through the chain rule).

    Returns (loss, dp, dq, dW, db).
    """
    nx = x_t.shape[0]
    r = r_mat.reshape(-1)  # row-major vec: r_{(i-1)Nx+j} then sums column

    y = output_layer(r, w, b)
    loss = cross_entropy(y, e)

    dz = y - e  # Eq. (25), through softmax
    db = dz  # Eq. (26)
    dw = jnp.outer(dz, r)  # Eq. (26)
    dr = (w.T @ dz).reshape(nx, nx + 1)  # Eq. (26)

    # Eq. (33): bpv_n = sum_j x(T-1)_j dL/dr_{(n-1)Nx+j} + dL/dr_{Nx^2+n},
    # scaled by the DPRR 1/T normalization
    inv_t = 1.0 / jnp.maximum(t_len, 1).astype(r.dtype)
    bpv = (dr[:, :nx] @ x_tm1 + dr[:, nx]) * inv_t

    # Eq. (34): dL/dx(T)_n = bpv_n + q * dL/dx(T)_{n+1}, reverse over n
    def rev_body(carry, b_n):
        dx_n = b_n + q * carry
        return dx_n, dx_n

    _, dx_rev = jax.lax.scan(rev_body, jnp.zeros((), r.dtype), bpv[::-1])
    dx = dx_rev[::-1]  # [Nx]

    # Eq. (35): dL/dp = sum_n f(j(T)_n + x(T-1)_n) dL/dx(T)_n
    dp = jnp.sum(f(j_t + x_tm1) * dx)

    # Eq. (36): dL/dq = sum_n x(T)_{n-1} dL/dx(T)_n, x(T)_0 = x(T-1)_{Nx}
    x_shift = jnp.concatenate([x_tm1[nx - 1 :], x_t[: nx - 1]])
    dq = jnp.sum(x_shift * dx)

    return loss, dp, dq, dw, db


# Reservoir-parameter gradients are clipped to ±GRAD_CLIP before the SGD
# update — mirrors rust/src/dfr/train.rs (f32 + per-sample SGD can spike
# early gradients past the p+q<1 stability boundary).
GRAD_CLIP = 1.0


def train_step(
    u, length, e, mask, p, q, w, b, lr_res, lr_out, f=ref.f_linear, use_pallas=True
):
    """One online SGD step (paper §4.1 protocol body).

    Returns (p', q', W', b', loss).
    """
    r_mat, x_t, x_tm1, j_t = forward(u, length, mask, p, q, f, use_pallas)
    loss, dp, dq, dw, db = truncated_grads(
        r_mat, x_t, x_tm1, j_t, e, p, q, w, b, length, f
    )
    dp = jnp.clip(dp, -GRAD_CLIP, GRAD_CLIP)
    dq = jnp.clip(dq, -GRAD_CLIP, GRAD_CLIP)
    return (
        p - lr_res * dp,
        q - lr_res * dq,
        w - lr_out * dw,
        b - lr_out * db,
        loss,
    )


def infer(u, length, mask, p, q, w_tilde, f=ref.f_linear, use_pallas=True):
    """Inference with the ridge-trained output layer W̃_out (Eq. 17).

    w_tilde: [C, s] acting on r̃ = [r, 1]. Returns class probabilities [C].
    """
    r_mat, _, _, _ = forward(u, length, mask, p, q, f, use_pallas)
    r_tilde = jnp.concatenate([r_mat.reshape(-1), jnp.ones((1,), u.dtype)])
    z = w_tilde @ r_tilde
    z = z - jnp.max(z)
    ez = jnp.exp(z)
    return ez / jnp.sum(ez)


def features(u, length, mask, p, q, f=ref.f_linear, use_pallas=True):
    """Reservoir representation r̃ = [r, 1] for the ridge accumulation
    path (the Rust coordinator folds r̃ into A and packed B)."""
    r_mat, _, _, _ = forward(u, length, mask, p, q, f, use_pallas)
    return jnp.concatenate([r_mat.reshape(-1), jnp.ones((1,), u.dtype)])


def stream_step(x_prev, u_t, mask, p, q, f=ref.f_linear, use_pallas=True):
    """Single streaming state update for the online serving path."""
    jk = mask @ u_t
    step_fn = res_k.reservoir_step if use_pallas else ref.reservoir_step_ref
    return step_fn(x_prev, jk, p, q, f)


# ---------------------------------------------------------------------------
# full-BPTT oracle (Eqs. 29-32) — used in tests to quantify what the
# truncation discards; not exported as an artifact.
# ---------------------------------------------------------------------------


def full_loss(u, length, mask, p, q, w, b, f=ref.f_linear):
    """Differentiable end-to-end loss for jax.grad (full BPTT oracle)."""
    r_mat, _, _, _ = forward(u, length, mask, p, q, f, use_pallas=False)
    return lambda e: cross_entropy(output_layer(r_mat.reshape(-1), w, b), e)


def truncated_surrogate_loss(u, length, e, mask, p, q, w, b, f=ref.f_linear):
    """Loss whose exact jax.grad wrt (p, q) equals the paper's truncated
    formulas (Eqs. 33-36): gradients flow ONLY through the last time
    step's contribution to r, with x(T-1) held constant.
    """
    sg = jax.lax.stop_gradient
    r_mat, x_t, x_tm1, j_t = forward(u, length, mask, p, q, f, use_pallas=False)
    # recompute x(T) differentiably from frozen x(T-1); the last-step
    # contribution enters R with the same 1/T normalization as forward()
    inv_t = 1.0 / jnp.maximum(length, 1).astype(u.dtype)
    x_t_diff = ref.reservoir_step_ref(sg(x_tm1), sg(j_t), p, q, f)
    prev_aug = jnp.concatenate([sg(x_tm1), jnp.ones((1,), u.dtype)])
    last_contrib = jnp.outer(x_t_diff, prev_aug) * inv_t
    r_sur = sg(r_mat - jnp.outer(x_t, prev_aug) * inv_t) + last_contrib
    y = output_layer(r_sur.reshape(-1), sg(w), sg(b))
    return cross_entropy(y, e)
