//! Co-design exploration: sweep the HLS configuration space of the FPGA
//! simulator — pipelining, write-buffer depth (RegSize), inlining —
//! across dataset shapes and print the Pareto frontier the paper's
//! Table 11 samples three points of.
//!
//! ```sh
//! cargo run --release --example fpga_codesign
//! ```

use dfr_edge::data::profiles::PROFILES;
use dfr_edge::fpga::design::{DesignConfig, SystemModel};
use dfr_edge::fpga::power::power_saving_fraction;
use dfr_edge::fpga::resource::{Arith, XC7Z020};
use dfr_edge::fpga::schedule::{
    accumulation_ii, accumulation_ii_arith, ridge_solve_cycles, ScheduleConfig, ShapeParams,
};
use dfr_edge::quant::{error_budget_sweep, QFormat};
use dfr_edge::report;

fn main() {
    // 1. the paper's three design points on the jpvow workload
    let prof = dfr_edge::data::profiles::Profile::by_name("jpvow").unwrap();
    let shape = ShapeParams::new(30, prof.n_v as u64, prof.n_c as u64, prof.t_max as u64);
    println!("## Table 11 configurations (jpvow)\n");
    println!(
        "{}",
        report::table11_markdown(shape, prof.train as u64, 25, 4, prof.test as u64)
    );

    // 2. RegSize sweep: Fig. 10's dependence-breaking in numbers
    println!("## write-buffer depth sweep (ridge solve, s = 931)\n");
    println!("{:>8} {:>4} {:>14} {:>10}", "RegSize", "II", "cycles", "speedup");
    let base = {
        let cfg = ScheduleConfig {
            pipelined: true,
            reg_size: 1,
            inline_state_update: false,
        };
        ridge_solve_cycles(&shape, &cfg)
    };
    for reg in [1u32, 2, 3, 4, 6, 8, 16] {
        let cfg = ScheduleConfig {
            pipelined: true,
            reg_size: reg,
            inline_state_update: false,
        };
        let c = ridge_solve_cycles(&shape, &cfg);
        println!(
            "{:>8} {:>4} {:>14} {:>9.2}x",
            reg,
            accumulation_ii(reg),
            c,
            base as f64 / c as f64
        );
    }

    // 3. does every dataset shape fit the chip? (resource feasibility)
    println!("\n## resource feasibility per dataset shape (standard config)\n");
    println!(
        "{:<8} {:>8} {:>6} {:>7} {:>8}",
        "dataset", "LUT%", "DSP%", "BRAM%", "fits?"
    );
    for p in &PROFILES {
        let shape = ShapeParams::new(30, p.n_v as u64, p.n_c as u64, p.t_max as u64);
        let m = SystemModel::new(shape, DesignConfig::Standard);
        let r = m.total_resources();
        let u = r.utilization(&XC7Z020);
        println!(
            "{:<8} {:>7.1}% {:>5.1}% {:>6.1}% {:>8}",
            p.name,
            100.0 * u.lut,
            100.0 * u.dsp,
            100.0 * u.bram36,
            if r.fits(&XC7Z020) { "yes" } else { "NO" }
        );
    }

    // 4. training-time scaling across dataset shapes (HW standard config)
    println!("\n## modelled HW training time per dataset (25 epochs, 4 betas)\n");
    println!("{:<8} {:>12} {:>12}", "dataset", "train (s)", "infer (s)");
    for p in &PROFILES {
        let shape = ShapeParams::new(30, p.n_v as u64, p.n_c as u64, p.t_max as u64);
        let m = SystemModel::new(shape, DesignConfig::Standard);
        println!(
            "{:<8} {:>12.2} {:>12.3}",
            p.name,
            m.training_seconds(p.train as u64, 25, 4),
            m.inference_seconds(p.test as u64)
        );
    }

    // 5. quantization: the Q-format error-budget sweep (measured
    //    deviation vs analytic bound vs accuracy) and its width-aware
    //    resource/power pricing on the Zynq
    println!("\n## Q-format error budget sweep (quant::sweep)\n");
    let formats = [QFormat::q4_12(), QFormat::q6_10(), QFormat::q8_8()];
    let rep = error_budget_sweep(&formats, 6, 0xC0DE);
    println!("{}", rep.markdown());
    let chosen = rep.choose(1e-2).map(|r| r.format).unwrap_or(QFormat::q6_10());
    println!("chosen width (bound ≤ 1e-2, no saturation): {}\n", chosen.name());

    // 6. the Table 11 Pareto story, width-aware: the paper's standard
    //    design on an f32 datapath vs the chosen fixed-point word
    println!("## width-aware resources/power (standard config, jpvow)\n");
    let f32_model = SystemModel::new(shape, DesignConfig::Standard);
    let q_model = SystemModel::with_arith(
        shape,
        DesignConfig::Standard,
        Arith::Fixed { bits: chosen.bits },
    );
    let rf = f32_model.total_resources();
    let rq = q_model.total_resources();
    println!(
        "{:<10} {:>8} {:>8} {:>8} {:>9} {:>9}",
        "datapath", "LUT", "FF", "DSP", "BRAM36", "power(W)"
    );
    for (name, r, p) in [
        ("f32", &rf, f32_model.power_w()),
        (chosen.name().as_str(), &rq, q_model.power_w()),
    ] {
        println!(
            "{:<10} {:>8} {:>8} {:>8} {:>9.1} {:>9.3}",
            name, r.lut, r.ff, r.dsp, r.bram36, p
        );
    }
    println!(
        "\n{} vs f32: LUT −{:.0}%, DSP −{:.0}%, power −{:.0}%; \
         RMW accumulation II {} → {} at RegSize=1 (1-cycle integer add \
         makes Algorithm 5's write buffer unnecessary)",
        chosen.name(),
        100.0 * (1.0 - rq.lut as f64 / rf.lut as f64),
        100.0 * (1.0 - rq.dsp as f64 / rf.dsp as f64),
        100.0 * f64::from(power_saving_fraction(&rf, &rq, 100e6)),
        accumulation_ii(1),
        accumulation_ii_arith(1, Arith::Fixed { bits: chosen.bits }),
    );
}
