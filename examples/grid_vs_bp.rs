//! Optimization-method comparison on one dataset: the §4.1 protocol's
//! truncated-BP against grid search at increasing resolution — a
//! single-dataset, human-readable version of Table 5 / Fig. 7.
//!
//! ```sh
//! cargo run --release --example grid_vs_bp -- ecg
//! ```

use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::dfr::grid;
use dfr_edge::dfr::mask::Mask;
use dfr_edge::dfr::train::{train, TrainConfig};
use dfr_edge::util::prng::Pcg32;
use dfr_edge::util::timer::fmt_secs;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "ecg".to_string());
    let Some(prof) = Profile::by_name(&name) else {
        eprintln!("unknown dataset '{name}'");
        std::process::exit(1);
    };
    let mut ds = synth::generate(prof, 42);
    // keep the sweep interactive for big datasets
    ds.train.truncate(200);
    ds.test.truncate(200);

    let cfg = TrainConfig::default();
    println!("dataset {name}: {} train / {} test, V={}, C={}", ds.train.len(), ds.test.len(), ds.n_v, ds.n_c);

    println!("\n== proposed: truncated-BP SGD ==");
    let model = train(&ds, &cfg);
    let bp_acc = model.test_accuracy(&ds);
    let bp_time = model.bp_seconds + model.ridge_seconds;
    println!(
        "p={:.4} q={:.4} beta={:.0e} acc={:.3} in {}",
        model.reservoir.p,
        model.reservoir.q,
        model.solution.beta,
        bp_acc,
        fmt_secs(bp_time)
    );
    println!("epoch losses: {:?}", &model.epoch_losses[..model.epoch_losses.len().min(8)]);

    println!("\n== baseline: grid search ==");
    let mask = Mask::random(cfg.nx, ds.n_v, &mut Pcg32::seed(cfg.seed));
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut cum = 0.0;
    for divs in 1..=5 {
        let r = grid::search(&ds, &mask, &cfg, divs, threads);
        cum += r.seconds;
        println!(
            "divs {divs}: best p={:.4} q={:.4} acc={:.3}  (sweep {}, cumulative {})",
            r.best.p,
            r.best.q,
            r.best.accuracy,
            fmt_secs(r.seconds),
            fmt_secs(cum)
        );
        if r.best.accuracy >= bp_acc {
            println!(
                "→ grid matched bp accuracy at divs={divs}; cumulative cost {:.1}x bp",
                cum / bp_time
            );
            break;
        }
    }
}
