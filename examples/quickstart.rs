//! Quickstart: train a modular DFR on the JPVOW-profile synthetic dataset
//! with the paper's §4.1 protocol (truncated-BP SGD + in-place Cholesky
//! ridge) and report test accuracy — the 60-second tour of the library.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dfr_edge::data::{profiles::Profile, synth};
use dfr_edge::dfr::train::{train, TrainConfig};
use dfr_edge::util::timer::fmt_secs;

fn main() {
    let profile = Profile::by_name("jpvow").expect("profile");
    println!("dataset: {} (V={}, C={}, Train={}, Test={})",
        profile.name, profile.n_v, profile.n_c, profile.train, profile.test);

    let ds = synth::generate(profile, 42);
    let cfg = TrainConfig::default();
    println!(
        "training: Nx={}, {} epochs, truncated-BP SGD + ridge (β sweep {:?})",
        cfg.nx, cfg.epochs, cfg.betas
    );

    let model = train(&ds, &cfg);
    println!(
        "reservoir parameters: p = {:.4}, q = {:.4} (init 0.01/0.01)",
        model.reservoir.p, model.reservoir.q
    );
    println!(
        "epoch losses: first {:.3} -> last {:.3}",
        model.epoch_losses.first().unwrap(),
        model.epoch_losses.last().unwrap()
    );
    println!(
        "ridge: beta = {:.0e}, memory = {} words",
        model.solution.beta, model.solution.memory_words
    );
    let acc = model.test_accuracy(&ds);
    println!(
        "test accuracy: {:.3}  (bp phase {}, ridge phase {})",
        acc,
        fmt_secs(model.bp_seconds),
        fmt_secs(model.ridge_seconds)
    );
}
