//! End-to-end driver: the paper's motivating use case (§1) — online
//! predictive maintenance of factory equipment — run through the FULL
//! stack: synthetic sensor streams → the coordinator's Collect →
//! BpOptimize → RidgeTrain → Serve lifecycle → live inference with
//! latency metrics, over the PJRT artifact engine when `make artifacts`
//! has run (fallback: the native engine), plus a drift event that
//! triggers online retraining.
//!
//! ```sh
//! make artifacts && cargo run --release --example predictive_maintenance
//! ```
//!
//! This is the repo's headline validation run; its output is recorded in
//! EXPERIMENTS.md §End-to-end.

use dfr_edge::coordinator::{
    Engine, NativeEngine, PjrtEngine, Request, Response, Server, ServerConfig, SessionConfig,
};
use dfr_edge::data::dataset::Sample;
use dfr_edge::data::profiles::Profile;
use dfr_edge::data::synth;
use dfr_edge::runtime::{DfrExecutor, Manifest};
use dfr_edge::util::timer::{fmt_secs, Stopwatch};

fn main() {
    // scenario: vibration+current sensors on a machine, jpvow-shaped
    // (V=12 channels, 9 equipment states: healthy + 8 fault modes)
    let profile = Profile::by_name("jpvow").unwrap();
    let ds = synth::generate(profile, 42);
    println!(
        "predictive-maintenance scenario: {} channels, {} machine states",
        profile.n_v, profile.n_c
    );

    // engine: PJRT artifacts when available (the paper's deployment path)
    let (engine, backend): (Box<dyn Engine>, &str) = match Manifest::load("artifacts")
        .and_then(|m| DfrExecutor::new(m.profile("jpvow")?))
    {
        Ok(exec) => {
            println!(
                "engine: PJRT ({}) over AOT artifacts — python is not running",
                exec.platform()
            );
            (Box::new(PjrtEngine::new(exec)), "pjrt")
        }
        Err(e) => {
            println!("engine: native (artifacts unavailable: {e:#})");
            (Box::new(NativeEngine::new(30, profile.n_c)), "native")
        }
    };

    // keep the online run at edge scale: collect 120 labelled windows
    let collect = 120;
    let mut scfg = SessionConfig::new(profile.n_v, profile.n_c, collect);
    let _ = backend;
    scfg.train.epochs = 25; // the paper's full protocol on both engines
    scfg.retrain_after = Some(60);
    let srv = Server::spawn(
        engine,
        ServerConfig {
            session: scfg,
            queue_cap: 256,
            seed: 42,
            // one machine = one session = one shard; see
            // benches/coordinator_throughput.rs for the multi-shard fleet
            shards: 1,
            max_batch: 8,
        },
    );

    // phase 1: stream labelled maintenance windows (technician-verified)
    let sw = Stopwatch::start();
    let mut train_info = None;
    for s in ds.train.iter().take(collect) {
        match srv
            .call(Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .expect("server alive")
        {
            Response::Trained {
                p,
                q,
                beta,
                train_seconds,
            } => {
                train_info = Some((p, q, beta, train_seconds));
            }
            Response::Rejected(m) => panic!("rejected: {m}"),
            _ => {}
        }
    }
    let (p, q, beta, tsecs) = train_info.expect("training triggered");
    println!(
        "online training done in {}: p={p:.4} q={q:.4} beta={beta:.0e}",
        fmt_secs(tsecs)
    );

    // phase 2: serve live inference traffic, measure accuracy + latency
    let n = ds.test.len();
    let mut correct = 0;
    let infer_sw = Stopwatch::start();
    for s in &ds.test {
        match srv
            .call(Request::Infer {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            Response::Prediction { class, scores } => {
                assert_eq!(scores.len(), profile.n_c);
                if class == s.label {
                    correct += 1;
                }
            }
            other => panic!("inference failed: {other:?}"),
        }
    }
    let infer_total = infer_sw.elapsed_secs();
    println!(
        "served {n} requests: accuracy {:.3}, throughput {:.0} req/s, mean latency {}",
        correct as f64 / n as f64,
        n as f64 / infer_total,
        fmt_secs(infer_total / n as f64)
    );

    // phase 3: drift event — the machine is refurbished, signals shift;
    // technicians stream fresh labelled windows and the session retrains
    let drifted: Vec<Sample> = ds
        .train
        .iter()
        .skip(collect)
        .take(60)
        .map(|s| {
            let mut s = s.clone();
            for x in s.u.iter_mut() {
                *x = 0.8 * *x + 0.1; // gain + offset drift
            }
            s
        })
        .collect();
    let mut retrained = false;
    for s in &drifted {
        if let Response::Trained { train_seconds, .. } = srv
            .call(Request::Labelled {
                session: 1,
                sample: s.clone(),
            })
            .unwrap()
        {
            println!("drift retraining completed in {}", fmt_secs(train_seconds));
            retrained = true;
        }
    }
    assert!(retrained, "drift retraining did not trigger");

    if let Response::StatsText(t) = srv.call(Request::Stats).unwrap() {
        println!("--- metrics ---\n{t}");
    }
    println!("total wall time {}", fmt_secs(sw.elapsed_secs()));
    srv.shutdown();
}
