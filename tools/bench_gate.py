#!/usr/bin/env python3
"""Bench regression gate.

Compares freshly measured bench medians (``rust/results/BENCH_*.json`` or
``results/BENCH_*.json``, written by ``cargo bench``) against the committed
root snapshots (``BENCH_*.json`` at the repo root) and fails if any median
regresses by more than the threshold (default 20%).

Leaf classification is by key name, matching the snapshot contract:

* higher-is-better: keys containing ``speedup`` or ending in ``_per_s``
  (throughput) — a regression is ``new < old * (1 - threshold)``
* lower-is-better: other keys ending in ``_s`` (seconds: medians, p99s) —
  a regression is ``new > old * (1 + threshold)``
* everything else (scale records, byte counts, comments) is ignored

A ``null`` on either side skips the comparison: the committed snapshots
carry null medians until the first bench run on a toolchain-bearing
machine replaces them (see each file's ``_comment``), and a smoke run may
legitimately omit rows. The gate therefore passes trivially on a
null-only baseline while still arming itself the moment real numbers are
committed.

``--update`` flips the direction of the tool: instead of gating, it
refreshes the committed root snapshots in place from the freshest
results, overwriting only the *measurable* leaves (the same
``leaf_direction`` classification the gate compares) and leaving
structure, ``_comment`` strings and ``scale`` records untouched. This is
how the null medians get replaced after the first bench run on a
toolchain-bearing machine: ``cargo bench && python3 tools/bench_gate.py
--update``, then commit the changed BENCH_*.json.

Exit status: 0 = no regressions (possibly everything skipped), 1 = at
least one regression, 2 = usage/IO error. ``--update`` exits 0 unless a
snapshot or results file cannot be read (2).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

SKIP_KEYS = {"_comment", "scale"}


def leaf_direction(key: str):
    """'up' if larger is better, 'down' if smaller is better, None to skip."""
    if "speedup" in key or key.endswith("_per_s"):
        return "up"
    if key.endswith("_s"):
        return "down"
    return None


def walk(baseline, fresh, path, out):
    """Collect (path, direction, old, new) rows for comparable numeric leaves."""
    if isinstance(baseline, dict) and isinstance(fresh, dict):
        for key, old in baseline.items():
            if key in SKIP_KEYS:
                continue
            if key not in fresh:
                out.append((f"{path}.{key}", "missing", old, None))
                continue
            walk(old, fresh[key], f"{path}.{key}", out)
    elif isinstance(baseline, list) and isinstance(fresh, list):
        for i, old in enumerate(baseline):
            if i >= len(fresh):
                out.append((f"{path}[{i}]", "missing", old, None))
                continue
            walk(old, fresh[i], f"{path}[{i}]", out)
    else:
        key = path.rsplit(".", 1)[-1].split("[", 1)[0]
        direction = leaf_direction(key)
        if direction is None:
            return
        out.append((path, direction, baseline, fresh))


def is_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def gate_file(baseline_path: Path, results_dirs, threshold: float):
    """Returns (regressions, compared, skipped) for one snapshot."""
    fresh_path = None
    for d in results_dirs:
        cand = d / baseline_path.name
        if cand.is_file():
            fresh_path = cand
            break
    if fresh_path is None:
        print(f"  {baseline_path.name}: no fresh run found — skipped")
        return 0, 0, 1

    baseline = json.loads(baseline_path.read_text())
    fresh = json.loads(fresh_path.read_text())
    rows = []
    walk(baseline, fresh, baseline_path.stem, rows)

    regressions = compared = skipped = 0
    for path, direction, old, new in rows:
        if direction == "missing" or not is_number(old) or not is_number(new):
            skipped += 1
            continue
        compared += 1
        if direction == "down":
            bad = old > 0 and new > old * (1.0 + threshold)
        else:
            bad = old > 0 and new < old * (1.0 - threshold)
        if bad:
            regressions += 1
            arrow = "slower" if direction == "down" else "lower"
            print(
                f"  REGRESSION {path}: {old:.6g} -> {new:.6g} "
                f"({abs(new - old) / old:+.1%} {arrow}, limit {threshold:.0%})"
            )
    print(
        f"  {baseline_path.name}: {compared} compared, "
        f"{skipped} skipped (null/missing), {regressions} regressed"
    )
    return regressions, compared, skipped


def merge_update(baseline, fresh, path, changed):
    """Overwrite baseline's measurable leaves in place with fresh values.

    Mirrors ``walk``'s traversal: only keys the gate would compare are
    touched, so comments, scale records and rows absent from the fresh
    run survive unchanged.
    """
    if isinstance(baseline, dict) and isinstance(fresh, dict):
        for key, old in baseline.items():
            if key in SKIP_KEYS or key not in fresh:
                continue
            new = fresh[key]
            if isinstance(old, (dict, list)):
                merge_update(old, new, f"{path}.{key}", changed)
            elif leaf_direction(key) is not None and is_number(new) and new != old:
                baseline[key] = new
                changed.append(f"{path}.{key}")
    elif isinstance(baseline, list) and isinstance(fresh, list):
        key = path.rsplit(".", 1)[-1].split("[", 1)[0]
        for i, old in enumerate(baseline):
            if i >= len(fresh):
                continue
            if isinstance(old, (dict, list)):
                merge_update(old, fresh[i], f"{path}[{i}]", changed)
            elif leaf_direction(key) is not None and is_number(fresh[i]) and fresh[i] != old:
                baseline[i] = fresh[i]
                changed.append(f"{path}[{i}]")


def update_file(baseline_path: Path, results_dirs) -> int:
    """Refresh one committed snapshot from results/. Returns leaves changed."""
    fresh_path = None
    for d in results_dirs:
        cand = d / baseline_path.name
        if cand.is_file():
            fresh_path = cand
            break
    if fresh_path is None:
        dirs = ", ".join(str(d) for d in results_dirs)
        print(
            f"  {baseline_path.name}: skipped — no fresh copy under {dirs}. "
            f"Run `cargo bench` (in rust/) first; it writes the results file "
            f"this mode copies medians from."
        )
        return 0

    baseline = json.loads(baseline_path.read_text())
    fresh = json.loads(fresh_path.read_text())
    changed = []
    merge_update(baseline, fresh, baseline_path.stem, changed)
    if changed:
        baseline_path.write_text(json.dumps(baseline, indent=2) + "\n")
        for p in changed:
            print(f"  updated {p}")
    print(
        f"  {baseline_path.name}: {len(changed)} median(s) refreshed "
        f"from {fresh_path}"
    )
    return len(changed)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--repo-root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root holding the committed BENCH_*.json snapshots",
    )
    ap.add_argument(
        "--results-dir",
        type=Path,
        action="append",
        default=None,
        help="directory with fresh BENCH_*.json (repeatable; default "
        "rust/results and results under the repo root)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="fractional regression tolerance on each median (default 0.20)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="refresh the committed snapshots' measurable medians in place "
        "from the freshest results instead of gating",
    )
    args = ap.parse_args()

    root = args.repo_root
    results_dirs = args.results_dir or [root / "rust" / "results", root / "results"]
    snapshots = sorted(root.glob("BENCH_*.json"))
    if not snapshots:
        print(f"no BENCH_*.json snapshots under {root}", file=sys.stderr)
        return 2

    if args.update:
        print(f"bench gate: refreshing committed medians in {root}")
        total = 0
        for snap in snapshots:
            try:
                total += update_file(snap, results_dirs)
            except (OSError, json.JSONDecodeError) as e:
                print(f"  {snap.name}: {e}", file=sys.stderr)
                return 2
        print(f"bench gate: {total} median(s) refreshed — review and commit")
        return 0

    print(f"bench gate: threshold {args.threshold:.0%}, baselines in {root}")
    total_reg = total_cmp = total_skip = 0
    for snap in snapshots:
        reg, cmp_, skip = gate_file(snap, results_dirs, args.threshold)
        total_reg += reg
        total_cmp += cmp_
        total_skip += skip
    print(
        f"bench gate: {total_cmp} compared, {total_skip} skipped, "
        f"{total_reg} regressed"
    )
    return 1 if total_reg else 0


if __name__ == "__main__":
    sys.exit(main())
